"""Paper-integration example: Spade guards the retrieval model's training
pipeline (DESIGN.md §4) — the transaction stream that would train the
two-tower model is first routed through the benign/urgent classifier;
transactions incident to the maintained fraud community are quarantined.

    PYTHONPATH=src python examples/fraud_aware_recsys.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.core import Spade
from repro.graphstore.generators import make_transaction_stream
from repro.models.two_tower import RecsysBatch, init_two_tower_params, two_tower_loss
from repro.train.optimizer import AdamConfig, init_train_state
from repro.train.train_step import make_train_step

# 1. fraud plane: maintain the community over the evolving transaction graph
stream = make_transaction_stream(n=4000, m=20000, seed=3)
sp = Spade(metric="DW", edge_grouping=True)
sp.LoadGraph(stream.base_src, stream.base_dst, stream.base_amt,
             n_vertices=stream.n_vertices)

quarantined, clean = [], []
for u, v, amt in zip(stream.inc_src, stream.inc_dst, stream.inc_amt):
    res = sp.InsertEdge(int(u), int(v), float(amt))
    comm = set(res.fraudsters.tolist()) if res.triggered else set()
    if int(u) in comm or int(v) in comm:
        quarantined.append((int(u), int(v)))
    else:
        clean.append((int(u), int(v), float(amt)))
frauds = set(sp.Detect()[0].tolist())
quarantined += [(u, v) for (u, v, a) in clean if u in frauds or v in frauds]
clean = [(u, v, a) for (u, v, a) in clean if u not in frauds and v not in frauds]
print(f"stream: {len(clean)} clean / {len(quarantined)} quarantined transactions")

# 2. training plane: two-tower retrieval on the CLEAN transactions only
cfg = get_smoke_config("two-tower-retrieval")
params = init_two_tower_params(jax.random.PRNGKey(0), cfg)
state = init_train_state(params)
step = make_train_step(lambda p, b: two_tower_loss(p, b, cfg),
                       AdamConfig(lr=1e-3, warmup_steps=1, weight_decay=0.0))

rng = np.random.default_rng(0)
B = 32
for it in range(20):
    take = rng.integers(0, len(clean), B)
    users = np.array([clean[i][0] for i in take]) % cfg.user_vocab
    items = np.array([clean[i][1] for i in take]) % cfg.item_vocab
    batch = RecsysBatch(
        user_idx=jnp.asarray(np.tile(users[:, None, None], (1, cfg.n_user_fields, cfg.multi_hot)), jnp.int32),
        user_wt=jnp.ones((B, cfg.n_user_fields, cfg.multi_hot), jnp.float32),
        item_idx=jnp.asarray(np.tile(items[:, None, None], (1, cfg.n_item_fields, cfg.multi_hot)), jnp.int32),
        item_wt=jnp.ones((B, cfg.n_item_fields, cfg.multi_hot), jnp.float32),
        log_q=jnp.zeros(B, jnp.float32),
    )
    state, metrics = step(state, batch)
print(f"retrieval training on clean stream: loss={float(metrics['loss']):.3f} "
      f"acc={float(metrics['in_batch_acc']):.2f}")
