"""End-to-end driver (the paper's deployment): a streaming fraud-detection
service replaying a timestamped transaction stream with edge grouping,
reporting the paper's latency / prevention-ratio / recall metrics for
every metric and batching policy.

    PYTHONPATH=src python examples/streaming_fraud_service.py
"""

from repro.graphstore.generators import make_transaction_stream
from repro.serve import EngineSpec, SpadeService

print(f"{'metric':<6} {'policy':<12} {'us/edge':>9} {'reorders':>9} "
      f"{'recall':>7} {'prevention':>11} {'latency_s':>10}")
for metric in ("DG", "DW", "FD"):
    for policy, kwargs in [
        ("batch-1", dict(grouping=False, batch_edges=1)),
        ("batch-100", dict(grouping=False, batch_edges=100)),
        ("grouping", dict(grouping=True, batch_edges=1, flush_every=0.5)),
    ]:
        stream = make_transaction_stream(n=8000, m=40000, seed=11)
        rep = SpadeService(metric, EngineSpec(plane="host", **kwargs)).run(stream)
        print(f"{metric:<6} {policy:<12} {rep.mean_us_per_edge:>9.1f} "
              f"{rep.n_reorders:>9} {rep.fraud_recall:>7.2f} "
              f"{str(rep.prevention_ratio and round(rep.prevention_ratio, 3)):>11} "
              f"{str(rep.detection_latency_s and round(rep.detection_latency_s, 4)):>10}")
