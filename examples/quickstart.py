"""Quickstart: detect an emerging fraud community on an evolving graph.

    PYTHONPATH=src python examples/quickstart.py

Mirrors the paper's Listing 2: plug in suspiciousness functions, load a
graph, stream transactions, watch the community update in real time.
"""

import numpy as np

from repro.core import Spade
from repro.graphstore.generators import make_transaction_stream

# 1. a transaction stream with a planted fraud ring + a new colluding actor
stream = make_transaction_stream(n=5000, m=25000, seed=7)

# 2. build Spade with the Fraudar (FD) semantics — or plug your own:
sp = Spade(metric="FD", edge_grouping=True)
# custom semantics are two lambdas away (paper Listing 1/2):
#   sp.VSusp(lambda u, g: my_account_prior(u))
#   sp.ESusp(lambda u, v, amount, g: my_tx_suspiciousness(u, v, amount))
sp.LoadGraph(stream.base_src, stream.base_dst, stream.base_amt,
             n_vertices=stream.n_vertices)

community, density = sp.Detect()
print(f"standing community: {len(community)} accounts, g(S^P) = {density:.2f}")

# 3. replay the stream; urgent transactions trigger immediate reordering
new_fraudsters = set()
for u, v, amt in zip(stream.inc_src, stream.inc_dst, stream.inc_amt):
    res = sp.InsertEdge(int(u), int(v), float(amt))
    if res.triggered and len(res.new_fraudsters):
        new_fraudsters.update(res.new_fraudsters.tolist())

sp.FlushBuffer()
community, density = sp.Detect()
actor = int(stream.fraud_block[0])
print(f"after stream: {len(community)} accounts, g(S^P) = {density:.2f}")
print(f"new fraudsters flagged during stream: {sorted(new_fraudsters)[:10]}")
print(f"planted colluding actor {actor} detected: {actor in set(community.tolist())}")
