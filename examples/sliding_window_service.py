"""Sliding-window fraud detection (paper Appendix C.3): the bounded-memory
deployment — only the base graph plus the last N ticks of transactions
stay resident; each tick expires the oldest batch and inserts the newest
in one fused warm re-peel.

Replays the same stream through the unbounded (insert-only) device
service and windowed services of several depths, reporting recall, tick
latency, and resident-edge footprint; then mirrors one window slide on
the host plane (Spade.InsertEdge + Spade.DeleteEdge — the exact oracle
the device plane is differential-tested against).

    PYTHONPATH=src python examples/sliding_window_service.py
"""

from repro.core import Spade
from repro.graphstore.generators import make_transaction_stream
from repro.serve import EngineSpec, SpadeService

stream = make_transaction_stream(n=5000, m=25000, seed=12)
m_base = stream.base_src.shape[0]

print(f"{'mode':<12} {'recall':>7} {'final_g':>10} {'live_edges':>11} "
      f"{'expired':>8} {'ms/tick':>8} {'ws/fb':>7}")
for label, window, ws in [("unbounded", 0, False), ("window-16", 16, False),
                          ("window-4", 4, False), ("workset-4", 4, True)]:
    spec = EngineSpec(batch_edges=512, max_rounds=20, refresh_every=16,
                      window_ticks=window, workset=ws)
    rep = SpadeService("DW", spec).run(stream)
    print(f"{label:<12} {rep.fraud_recall:>7.2f} {rep.final_g:>10.1f} "
          f"{rep.live_edges:>11} {rep.n_expired_edges:>8} "
          f"{1e3 * rep.mean_tick_seconds:>8.1f} "
          f"{rep.n_workset_ticks:>3}/{rep.n_fallback_ticks:<3}")

# host-plane mirror of one window slide: exact incremental delete (C.1)
sp = Spade(metric="DW")
sp.LoadGraph(stream.base_src[:2000], stream.base_dst[:2000],
             stream.base_amt[:2000], n_vertices=stream.n_vertices)
u, v = int(stream.inc_src[0]), int(stream.inc_dst[0])
if u != v:
    sp.InsertEdge(u, v, float(stream.inc_amt[0]))   # tick in ...
    res = sp.DeleteEdge(u, v)                       # ... and expired
    print(f"\nhost slide: g(S^P) after insert+expire = {res.g_best:.2f} "
          f"(community size {len(res.fraudsters)})")
