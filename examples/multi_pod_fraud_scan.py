"""Device-plane example: bulk peeling + incremental maintenance of a
million-edge evolving graph with the TPU-native engine (runs on CPU here;
the same program is what the multi-pod dry-run shards over 512 chips).

    PYTHONPATH=src python examples/multi_pod_fraud_scan.py
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.incremental import benign_mask, init_state, insert_and_maintain
from repro.graphstore.generators import make_power_law_graph
from repro.graphstore.structs import device_graph_from_coo

n, m = 200_000, 1_000_000
src, dst, amt = make_power_law_graph(n, m, seed=0, alpha=0.5)
# plant a fraud ring
ring = np.arange(50)
rs = np.repeat(ring, 20)
rd = ring[(np.arange(rs.shape[0]) * 7) % 50]
keep = rs != rd
src = np.concatenate([src, rs[keep]])
dst = np.concatenate([dst, rd[keep]])
amt = np.concatenate([amt, np.full(keep.sum(), 100.0)])

g = device_graph_from_coo(n, src, dst, amt.astype(np.float32),
                          e_capacity=src.shape[0] + 1 << 20)
t0 = time.perf_counter()
state = init_state(g, eps=0.1)
jax.block_until_ready(state.best_g)
print(f"bulk peel over {src.shape[0]:,} edges: {time.perf_counter()-t0:.2f}s, "
      f"g_best={float(state.best_g):.1f}, "
      f"community={int(state.community.sum())} vertices")

rng = np.random.default_rng(1)
B = 4096
for tick in range(3):
    bs = jnp.asarray(rng.integers(0, n, B), jnp.int32)
    bd = jnp.asarray(rng.integers(0, n, B), jnp.int32)
    bc = jnp.ones(B, jnp.float32)
    valid = bs != bd
    bm = benign_mask(state, bs, bd, bc)
    t0 = time.perf_counter()
    state = insert_and_maintain(state, bs, bd, bc, valid, eps=0.1)
    jax.block_until_ready(state.best_g)
    print(f"tick {tick}: {int(valid.sum())} edges ({int(bm.sum())} benign) "
          f"maintained in {time.perf_counter()-t0:.3f}s, "
          f"g_best={float(state.best_g):.1f}")
