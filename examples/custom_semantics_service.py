"""A user-defined suspiciousness semantics on every engine — the paper's
§6 API promise, end to end.

We define a HoloScope-flavored **time-decayed** semantics: a transaction's
suspiciousness is its amount discounted by how far it sits from the
stream's detection horizon (``2^-(age / half_life)``), so evidence
concentrated in a recent burst dominates stale background mass — the
temporal-spike intuition behind HoloScope's weighting, expressed as a
Spade semantics in ~10 lines.  Spade incrementalizes it for free: the same
definition runs through

* the single-device sliding-window engine,
* the affected-area workset engine with the predictive bucket selector,
* the mesh-sharded engine (8 forced CPU host devices),

with **zero engine-file edits** — the hooks are compiled at the protocol
boundary (``seed_base`` / ``batch_weights``), never dispatched by name
inside an engine.

    PYTHONPATH=src python examples/custom_semantics_service.py
"""

import os

# mesh plane below wants 8 host devices; must be set before jax init
if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count=8 "
        + os.environ.get("XLA_FLAGS", "")
    ).strip()

import jax

from repro.core.semantics import SuspSemantics, register
from repro.graphstore.generators import make_transaction_stream
from repro.serve import EngineSpec, SpadeService

stream = make_transaction_stream(n=5000, m=25000, seed=12)

# ---------------------------------------------------------------------------
# the custom semantics: amount x recency decay toward the stream horizon.
# `xp` is numpy on the host/seeding paths (float64, dyadic-snapped at the
# protocol boundary) and jax.numpy inside the jitted tick — one definition,
# every plane.  `aux` is the per-edge transaction timestamp the bundled
# services feed (base-graph edges carry t = 0).
# ---------------------------------------------------------------------------

HORIZON = float(stream.inc_time.max())
HALF_LIFE = 0.25 * HORIZON


def _decayed_amount(xp, src, dst, raw, in_deg_dst, t):
    age = HORIZON - (0.0 if t is None else t)
    return xp.maximum(raw, 1e-12) * 2.0 ** (-age / HALF_LIFE)


TDW = register(SuspSemantics(name="TDW", esusp=_decayed_amount, uses_aux=True))

# ---------------------------------------------------------------------------
# the same semantics through three engines (and DW as the undecayed control)
# ---------------------------------------------------------------------------

mesh = jax.make_mesh((8,), ("data",))
CONFIGS = [
    ("DW window-4", "DW",
     EngineSpec(batch_edges=512, max_rounds=20, refresh_every=16,
                window_ticks=4)),
    ("TDW window-4", "TDW",
     EngineSpec(batch_edges=512, max_rounds=20, refresh_every=16,
                window_ticks=4)),
    ("TDW workset-4", "TDW",
     EngineSpec(batch_edges=512, max_rounds=20, refresh_every=16,
                window_ticks=4, workset=True, predictive=True)),
    ("TDW mesh-8", "TDW",
     EngineSpec(batch_edges=512, max_rounds=20, refresh_every=16,
                window_ticks=4, mesh=mesh)),
]

print(f"{'engine':<14} {'recall':>7} {'final_g':>10} {'live':>7} "
      f"{'ms/tick':>8} {'ws/fb':>6} {'pred/miss':>10}")
for label, sem, spec in CONFIGS:
    rep = SpadeService(sem, spec).run(stream)
    print(f"{label:<14} {rep.fraud_recall:>7.2f} {rep.final_g:>10.1f} "
          f"{rep.live_edges:>7} {1e3 * rep.mean_tick_seconds:>8.1f} "
          f"{rep.n_workset_ticks:>3}/{rep.n_fallback_ticks:<2} "
          f"{rep.n_predicted_ticks:>5}/{rep.n_bucket_miss_ticks:<4}")

# the registry now knows the custom name everywhere a builtin works: the
# host oracle compiles the same hooks through its per-edge funnel
from repro.core import Spade  # noqa: E402

sp = Spade(metric="TDW")
sp.LoadGraph(stream.base_src[:2000], stream.base_dst[:2000],
             stream.base_amt[:2000], n_vertices=stream.n_vertices)
comm, g_best = sp.Detect()
print(f"\nhost oracle under TDW: g(S^P) = {g_best:.2f} "
      f"(community size {len(comm)})")
