"""Train a reduced LM (same code path as the production launcher) for a few
hundred steps with checkpointing + straggler watchdog.

    PYTHONPATH=src python examples/train_lm.py [--steps 200]

The full-size configs are exercised via the multi-pod dry-run; this driver
is the end-to-end training loop at CPU-feasible scale.
"""

import argparse
import sys

sys.argv = [sys.argv[0], "--arch", "qwen3-14b", "--smoke",
            "--steps", (sys.argv[sys.argv.index("--steps") + 1]
                        if "--steps" in sys.argv else "60"),
            "--ckpt-dir", "/tmp/repro_lm_ckpt"]
from repro.launch.train import main  # noqa: E402

main()
