"""Segment-op substrate: the message-passing primitive for GNNs, peeling,
and embedding bags (JAX has no EmbeddingBag / CSR — this module IS that
layer, built on ``jax.ops.segment_sum`` / gather).

Also provides the padded-CSR blocking used by the Pallas ``gather_segsum``
kernel (fixed nonzeros per row block; long rows split across blocks).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "segment_sum",
    "segment_mean",
    "segment_max",
    "segment_softmax",
    "gather_scatter_sum",
    "embedding_bag",
    "PaddedCSR",
    "build_padded_csr",
]


def segment_sum(data, segment_ids, num_segments):
    return jax.ops.segment_sum(data, segment_ids, num_segments=num_segments)


def segment_mean(data, segment_ids, num_segments, eps: float = 1e-9):
    s = jax.ops.segment_sum(data, segment_ids, num_segments=num_segments)
    ones = jnp.ones(data.shape[:1], dtype=data.dtype)
    cnt = jax.ops.segment_sum(ones, segment_ids, num_segments=num_segments)
    return s / jnp.maximum(cnt, eps)[(...,) + (None,) * (data.ndim - 1)]


def segment_max(data, segment_ids, num_segments):
    return jax.ops.segment_max(data, segment_ids, num_segments=num_segments)


def segment_softmax(logits, segment_ids, num_segments):
    """Numerically-stable softmax over variable-length segments (edge
    softmax for GAT)."""
    m = jax.ops.segment_max(logits, segment_ids, num_segments=num_segments)
    m = jnp.where(jnp.isfinite(m), m, 0.0)
    z = jnp.exp(logits - m[segment_ids])
    denom = jax.ops.segment_sum(z, segment_ids, num_segments=num_segments)
    return z / (denom[segment_ids] + 1e-9)


def gather_scatter_sum(x, src_idx, dst_idx, num_segments, edge_weight=None):
    """The GNN aggregation: out[d] = sum_{edges e: dst=d} w_e * x[src_e].

    = SpMM with a COO adjacency; the Pallas kernel in
    ``repro.kernels.gather_segsum`` implements the same contract.
    """
    msgs = x[src_idx]
    if edge_weight is not None:
        msgs = msgs * edge_weight[:, None]
    return jax.ops.segment_sum(msgs, dst_idx, num_segments=num_segments)


def embedding_bag(table, indices, offsets_ids, num_bags, weights=None, combine="sum"):
    """EmbeddingBag (torch parity, built from gather + segment ops).

    ``indices``: flat int32 lookups into ``table``; ``offsets_ids``: bag id
    per lookup.  ``combine`` in {sum, mean}.
    """
    rows = jnp.take(table, indices, axis=0)
    if weights is not None:
        rows = rows * weights[:, None]
    if combine == "sum":
        return jax.ops.segment_sum(rows, offsets_ids, num_segments=num_bags)
    if combine == "mean":
        return segment_mean(rows, offsets_ids, num_bags)
    raise ValueError(f"combine={combine}")


# ---------------------------------------------------------------------------
# padded CSR blocking (for the Pallas kernel)
# ---------------------------------------------------------------------------


class PaddedCSR(NamedTuple):
    """Fixed-shape CSR blocks: ``rows x nnz_per_block`` column indices.

    ``col[b, j]`` is the source index of the j-th nonzero handled by block
    b; ``row[b, j]`` its destination row; padding entries point at row
    ``num_rows`` (dropped).  Every block owns a contiguous row range, long
    rows are split across consecutive blocks (their partial sums scatter-add
    into the same row).
    """

    col: np.ndarray  # int32 [n_blocks, nnz_per_block]
    row: np.ndarray  # int32 [n_blocks, nnz_per_block]
    val: np.ndarray  # float32 [n_blocks, nnz_per_block]
    num_rows: int
    nnz_per_block: int


def build_padded_csr(
    dst: np.ndarray,
    src: np.ndarray,
    val: np.ndarray | None,
    num_rows: int,
    nnz_per_block: int = 1024,
) -> PaddedCSR:
    """Pack COO (sorted by dst) into fixed-size blocks."""
    dst = np.asarray(dst, np.int32)
    src = np.asarray(src, np.int32)
    order = np.argsort(dst, kind="stable")
    dst, src = dst[order], src[order]
    v = (
        np.ones(dst.shape[0], np.float32)
        if val is None
        else np.asarray(val, np.float32)[order]
    )
    nnz = dst.shape[0]
    n_blocks = max(1, (nnz + nnz_per_block - 1) // nnz_per_block)
    tot = n_blocks * nnz_per_block
    pad = tot - nnz
    col = np.concatenate([src, np.zeros(pad, np.int32)]).reshape(n_blocks, -1)
    row = np.concatenate([dst, np.full(pad, num_rows, np.int32)]).reshape(n_blocks, -1)
    vv = np.concatenate([v, np.zeros(pad, np.float32)]).reshape(n_blocks, -1)
    return PaddedCSR(col=col, row=row, val=vv, num_rows=num_rows,
                     nnz_per_block=nnz_per_block)
