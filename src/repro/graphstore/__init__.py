"""Graph substrate: device COO/CSR structures, segment-op message passing,
stream generators, neighbor sampling, and mesh partitioning."""

from .generators import DATASET_STATS, TxStream, make_power_law_graph, make_transaction_stream
from .sampler import CSRNeighbors, SampledBlock, build_csr_neighbors, sample_fanout
from .segment_ops import (
    PaddedCSR,
    build_padded_csr,
    embedding_bag,
    gather_scatter_sum,
    segment_max,
    segment_mean,
    segment_softmax,
    segment_sum,
)
from .structs import (
    DeviceGraph,
    append_edges,
    csr_sort,
    device_graph_from_coo,
    remove_edges,
)

__all__ = [
    "DeviceGraph",
    "device_graph_from_coo",
    "append_edges",
    "remove_edges",
    "csr_sort",
    "segment_sum",
    "segment_mean",
    "segment_max",
    "segment_softmax",
    "gather_scatter_sum",
    "embedding_bag",
    "PaddedCSR",
    "build_padded_csr",
    "TxStream",
    "make_transaction_stream",
    "make_power_law_graph",
    "DATASET_STATS",
    "CSRNeighbors",
    "SampledBlock",
    "build_csr_neighbors",
    "sample_fanout",
]
