"""Fanout neighbor sampler (GraphSAGE-style) for ``minibatch_lg``.

Produces fixed-shape sampled subgraphs (TPU-friendly: every batch has
identical shapes; short neighborhoods are padded with self-edges of weight
0).  The CSR neighbor table lives on host (NumPy) — sampling is a data-
pipeline stage; the sampled block is what ships to device.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

__all__ = ["CSRNeighbors", "SampledBlock", "build_csr_neighbors", "sample_fanout"]


@dataclass
class CSRNeighbors:
    indptr: np.ndarray  # int64 [n+1]
    indices: np.ndarray  # int32 [m]
    n: int


@dataclass
class SampledBlock:
    """Fixed-shape k-hop sampled subgraph.

    ``nodes``: unique node ids, seeds first (padded with -1 -> mapped to 0).
    ``edge_src/edge_dst``: local indices into ``nodes``; ``edge_mask``
    marks real edges.  Shapes depend only on (batch, fanouts).
    """

    nodes: np.ndarray
    seeds: np.ndarray
    edge_src: np.ndarray
    edge_dst: np.ndarray
    edge_mask: np.ndarray
    node_mask: np.ndarray


def build_csr_neighbors(n: int, src: np.ndarray, dst: np.ndarray) -> CSRNeighbors:
    order = np.argsort(dst, kind="stable")
    s = np.asarray(src, np.int32)[order]
    d = np.asarray(dst)[order]
    indptr = np.zeros(n + 1, dtype=np.int64)
    np.add.at(indptr, d + 1, 1)
    np.cumsum(indptr, out=indptr)
    return CSRNeighbors(indptr=indptr, indices=s, n=n)


def sample_fanout(
    csr: CSRNeighbors,
    seeds: np.ndarray,
    fanouts: tuple[int, ...],
    rng: np.random.Generator,
) -> SampledBlock:
    """Uniform fanout sampling; fixed shapes for all batches."""
    seeds = np.asarray(seeds, np.int64)
    frontier = seeds
    all_src, all_dst = [], []
    for f in fanouts:
        deg = csr.indptr[frontier + 1] - csr.indptr[frontier]
        # sample f neighbors per frontier node (with replacement; deg==0 -> self)
        offs = (rng.random((frontier.shape[0], f)) * np.maximum(deg, 1)[:, None]).astype(
            np.int64
        )
        nbr = csr.indices[csr.indptr[frontier][:, None] + offs].astype(np.int64)
        nbr = np.where(deg[:, None] > 0, nbr, frontier[:, None])  # self-pad
        src = nbr.reshape(-1)
        dst = np.repeat(frontier, f)
        all_src.append(src)
        all_dst.append(dst)
        frontier = np.unique(src)

    src = np.concatenate(all_src)
    dst = np.concatenate(all_dst)
    nodes, inv = np.unique(np.concatenate([seeds, src, dst]), return_inverse=True)
    # stable remap with seeds first
    seed_pos = np.searchsorted(nodes, seeds)
    perm = np.concatenate([seed_pos, np.setdiff1d(np.arange(nodes.shape[0]), seed_pos)])
    rank = np.empty_like(perm)
    rank[perm] = np.arange(perm.shape[0])
    nodes_ordered = nodes[perm]
    local = rank[inv]
    k = seeds.shape[0]
    return SampledBlock(
        nodes=nodes_ordered.astype(np.int64),
        seeds=np.arange(k, dtype=np.int64),
        edge_src=local[k : k + src.shape[0]].astype(np.int32),
        edge_dst=local[k + src.shape[0] :].astype(np.int32),
        edge_mask=np.ones(src.shape[0], dtype=bool),
        node_mask=np.ones(nodes_ordered.shape[0], dtype=bool),
    )
