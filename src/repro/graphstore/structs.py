"""Device-resident graph structures (fixed-capacity, shard-ready).

TPU/XLA requires static shapes, so the evolving transaction graph lives in
fixed-capacity COO buffers with validity masks.  Edge insertion appends into
pre-allocated slots; capacity growth is a host-side reallocation (amortized,
off the latency path).  All fields are leading-dim shardable:

* edge arrays ``src/dst/c/edge_mask``  → partitioned over ``(pod, data)``
* vertex arrays ``a/vertex_mask``      → replicated or sharded over ``model``
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["DeviceGraph", "device_graph_from_coo", "compact_slots",
           "append_edges", "remove_edges", "csr_sort"]


def compact_slots(
    offset: jax.Array, valid: jax.Array, capacity: int
) -> tuple[jax.Array, jax.Array]:
    """Compacted append slots: the k-th valid lane gets ``offset + k``.

    Returns ``(idx, ok)``; lanes with ``~ok`` (invalid, or past capacity)
    must be dropped by the caller.  Shared by the single-device and the
    edge-sharded append so their slot semantics cannot diverge.
    """
    slot = jnp.cumsum(valid.astype(jnp.int32)) - 1
    idx = offset + slot
    return idx, valid & (idx < capacity)


@partial(
    jax.tree_util.register_dataclass,
    data_fields=["src", "dst", "c", "edge_mask", "a", "vertex_mask"],
    meta_fields=["n_capacity", "e_capacity"],
)
@dataclasses.dataclass(frozen=True)
class DeviceGraph:
    """Fixed-capacity COO transaction graph on device.

    ``src[i] -> dst[i]`` with suspiciousness ``c[i]`` where ``edge_mask[i]``.
    Invalid slots carry ``src = dst = n_capacity - 1`` padding self-loops with
    ``c = 0`` so segment ops need no extra masking of indices.
    """

    src: jax.Array  # int32 [E_cap]
    dst: jax.Array  # int32 [E_cap]
    c: jax.Array  # float32 [E_cap]
    edge_mask: jax.Array  # bool [E_cap]
    a: jax.Array  # float32 [V_cap] vertex suspiciousness
    vertex_mask: jax.Array  # bool [V_cap]
    n_capacity: int
    e_capacity: int

    @property
    def n_vertices(self) -> jax.Array:
        return jnp.sum(self.vertex_mask)

    @property
    def n_edges(self) -> jax.Array:
        return jnp.sum(self.edge_mask)

    def f_total(self) -> jax.Array:
        """f(V): total graph suspiciousness (Eq. 1)."""
        return jnp.sum(jnp.where(self.vertex_mask, self.a, 0.0)) + jnp.sum(
            jnp.where(self.edge_mask, self.c, 0.0)
        )

    def peel_weights(self) -> jax.Array:
        """w_u(S_0) for every vertex: a_u + incident suspiciousness."""
        cm = jnp.where(self.edge_mask, self.c, 0.0)
        w = jnp.where(self.vertex_mask, self.a, 0.0)
        w = w + jax.ops.segment_sum(cm, self.src, num_segments=self.n_capacity)
        w = w + jax.ops.segment_sum(cm, self.dst, num_segments=self.n_capacity)
        return w


def device_graph_from_coo(
    n: int,
    src: np.ndarray,
    dst: np.ndarray,
    c: np.ndarray | None = None,
    a: np.ndarray | None = None,
    n_capacity: int | None = None,
    e_capacity: int | None = None,
) -> DeviceGraph:
    """Build a DeviceGraph from host COO arrays (padding to capacity)."""
    src = np.asarray(src, dtype=np.int32)
    dst = np.asarray(dst, dtype=np.int32)
    m = src.shape[0]
    c = np.ones(m, dtype=np.float32) if c is None else np.asarray(c, dtype=np.float32)
    n_cap = int(n_capacity or n)
    e_cap = int(e_capacity or max(m, 1))
    if n_cap < n or e_cap < m:
        raise ValueError("capacity smaller than graph")
    pad_e = e_cap - m
    pad_idx = np.full(pad_e, n_cap - 1, dtype=np.int32)
    av = np.zeros(n_cap, dtype=np.float32)
    if a is not None:
        av[:n] = np.asarray(a, dtype=np.float32)
    return DeviceGraph(
        src=jnp.asarray(np.concatenate([src, pad_idx])),
        dst=jnp.asarray(np.concatenate([dst, pad_idx])),
        c=jnp.asarray(np.concatenate([c, np.zeros(pad_e, np.float32)])),
        edge_mask=jnp.asarray(
            np.concatenate([np.ones(m, bool), np.zeros(pad_e, bool)])
        ),
        a=jnp.asarray(av),
        vertex_mask=jnp.asarray(np.arange(n_cap) < n),
        n_capacity=n_cap,
        e_capacity=e_cap,
    )


def append_edges(
    g: DeviceGraph,
    offset: jax.Array,
    src: jax.Array,
    dst: jax.Array,
    c: jax.Array,
    valid: jax.Array | None = None,
) -> DeviceGraph:
    """Write a batch of edges into consecutive slots from ``offset``.

    ``offset`` is the current edge count (host-tracked or device scalar);
    batch size B is static.  Valid entries are *compacted*: the k-th valid
    edge lands in slot ``offset + k``, so the slot range consumed always
    equals ``sum(valid)`` — the amount callers advance their edge counter
    by — even when invalid entries sit between valid ones.  Out-of-capacity
    writes are dropped (callers reallocate on host when the high-water mark
    approaches capacity).
    """
    B = src.shape[0]
    v = jnp.ones(B, bool) if valid is None else valid
    idx, ok = compact_slots(offset, v, g.e_capacity)
    # dropped writes go out of bounds and are discarded by mode='drop'
    idx = jnp.where(ok, idx, g.e_capacity)
    return dataclasses.replace(
        g,
        src=g.src.at[idx].set(src.astype(jnp.int32), mode="drop"),
        dst=g.dst.at[idx].set(dst.astype(jnp.int32), mode="drop"),
        c=g.c.at[idx].set(c.astype(jnp.float32), mode="drop"),
        edge_mask=g.edge_mask.at[idx].set(True, mode="drop"),
    )


def remove_edges(g: DeviceGraph, drop: jax.Array) -> tuple[DeviceGraph, jax.Array]:
    """Tombstone the slots where ``drop`` holds and compact the survivors.

    The k-th surviving edge (in slot order) moves to slot ``k`` — the same
    slot semantics as ``compact_slots`` on the append path, so insertion
    order is preserved and the live region stays a prefix.  Sliding-window
    callers exploit this: after every expiry the oldest batch is again the
    first ``count`` slots.  Freed slots revert to the standard inert
    padding (``src = dst = n_capacity - 1``, ``c = 0``, mask False).

    The compaction runs as a **gather**: output slot ``k`` pulls the k-th
    survivor, located by binary search over the survivor-count prefix sum
    (``searchsorted``).  The scatter formulation (full-buffer ``.at[].set``
    with cumsum slots) was tried and REFUTED: XLA CPU scatters cost ~4x a
    sorted-search gather at 400k edges, and this pass sits on the serving
    tick's critical path.  The compacted mask is just ``slot < survivors``
    — no scatter at all.

    Returns ``(graph, n_removed)`` with ``n_removed`` the number of *live*
    edges dropped (tombstoning an already-dead slot is a no-op).
    """
    pad = jnp.int32(g.n_capacity - 1)
    E = g.e_capacity
    survive = g.edge_mask & ~drop
    csum = jnp.cumsum(survive.astype(jnp.int32))
    n_survive = csum[E - 1]
    # slot k (0-based) takes the (k+1)-th survivor: the first index whose
    # running survivor count reaches k+1
    idx = jnp.searchsorted(csum, jnp.arange(1, E + 1, dtype=jnp.int32))
    live = jnp.arange(E, dtype=jnp.int32) < n_survive
    idx = jnp.where(live, idx, E - 1)  # clamp dead lanes (values masked below)
    n_removed = jnp.sum(g.edge_mask & drop).astype(jnp.int32)
    return (
        dataclasses.replace(
            g,
            src=jnp.where(live, g.src[idx], pad),
            dst=jnp.where(live, g.dst[idx], pad),
            c=jnp.where(live, g.c[idx], 0.0),
            edge_mask=live,
        ),
        n_removed,
    )


def csr_sort(g: DeviceGraph) -> DeviceGraph:
    """Sort edge slots by (src, dst) for locality (host-side utility)."""
    src = np.asarray(g.src)
    dst = np.asarray(g.dst)
    order = np.lexsort((dst, src))
    return dataclasses.replace(
        g,
        src=jnp.asarray(src[order]),
        dst=jnp.asarray(dst[order]),
        c=jnp.asarray(np.asarray(g.c)[order]),
        edge_mask=jnp.asarray(np.asarray(g.edge_mask)[order]),
    )
