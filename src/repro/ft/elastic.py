"""Elastic scaling + straggler mitigation.

*Elastic re-mesh*: on node loss, rebuild the largest valid mesh from the
surviving device count and reshard the latest checkpoint onto it
(``load_pytree(..., shardings=new)``).  Meshes are required to keep the
'model' axis intact (TP groups are not survivable); capacity changes are
absorbed by the 'data'/'pod' axes — the global batch is then re-split.

*Straggler mitigation*: a step-commit watchdog — if a step exceeds
``timeout x median(step_time)``, the driver marks the step lost, restores
from the last committed checkpoint, and (on a real cluster) excludes the
straggler host via the cluster agent hook.  Here the hook is injectable so
tests can simulate hangs.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Sequence

import jax
import numpy as np
from jax.sharding import Mesh

__all__ = ["best_mesh_for", "StepWatchdog", "ElasticPlan", "replan"]


def best_mesh_for(
    n_devices: int, model_axis: int, axis_names=("data", "model"), devices=None
) -> Mesh:
    """Largest (data, model) mesh with the TP axis preserved."""
    if n_devices < model_axis:
        raise ValueError(
            f"cannot preserve model axis {model_axis} with {n_devices} devices"
        )
    data = n_devices // model_axis
    devs = np.asarray(devices if devices is not None else jax.devices())[
        : data * model_axis
    ]
    return Mesh(devs.reshape(data, model_axis), axis_names)


@dataclasses.dataclass
class ElasticPlan:
    mesh: Mesh
    global_batch: int
    per_replica_batch: int


def replan(
    n_devices: int, model_axis: int, global_batch: int, devices=None
) -> ElasticPlan:
    """Recompute mesh + batch split after a capacity change; the global
    batch is preserved (gradient semantics unchanged) as long as it divides
    the new data-parallel degree."""
    mesh = best_mesh_for(n_devices, model_axis, devices=devices)
    dp = mesh.devices.shape[0]
    while global_batch % dp != 0:
        dp -= 1  # shrink dp by trimming stragglers off the mesh
        mesh = best_mesh_for(dp * model_axis, model_axis, devices=devices)
    return ElasticPlan(mesh=mesh, global_batch=global_batch,
                       per_replica_batch=global_batch // dp)


class StepWatchdog:
    """Detects straggling/hung steps by comparing against a running median."""

    def __init__(self, factor: float = 3.0, min_history: int = 5,
                 on_straggler: Callable[[int, float], None] | None = None):
        self.factor = factor
        self.min_history = min_history
        self.on_straggler = on_straggler
        self.history: list[float] = []
        self.flagged: list[int] = []

    def observe(self, step: int, seconds: float) -> bool:
        """Returns True if the step is deemed a straggler."""
        is_bad = False
        if len(self.history) >= self.min_history:
            med = float(np.median(self.history))
            if seconds > self.factor * med:
                is_bad = True
                self.flagged.append(step)
                if self.on_straggler:
                    self.on_straggler(step, seconds)
        if not is_bad:
            self.history.append(seconds)
            self.history = self.history[-128:]
        return is_bad
