"""Async sharded checkpointing (no orbax in this environment — built from
scratch): per-leaf .npy shards + JSON manifest, atomic rename commit,
keep-last-k retention, async writer thread, restore with *resharding*
(restore onto any mesh: leaves are device_put against target shardings).

Layout:
  <dir>/step_000420.tmp/...   (in-flight)
  <dir>/step_000420/manifest.json + leaf_<i>.npy   (committed)
"""

from __future__ import annotations

import json
import os
import queue
import shutil
import threading
import time
from typing import Any

import jax
import numpy as np

__all__ = ["CheckpointManager", "save_pytree", "load_pytree", "latest_step"]


def _leaf_paths(tree: Any) -> list[str]:
    paths = []
    for kp, _ in jax.tree_util.tree_flatten_with_path(tree)[0]:
        paths.append(jax.tree_util.keystr(kp))
    return paths


def save_pytree(tree: Any, directory: str, step: int) -> str:
    """Synchronous atomic save. Returns the committed directory."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    leaves, treedef = jax.tree.flatten(tree)
    manifest = {
        "step": step,
        "n_leaves": len(leaves),
        "paths": _leaf_paths(tree),
        "dtypes": [str(np.asarray(x).dtype) for x in leaves],
        "shapes": [list(np.asarray(x).shape) for x in leaves],
        "time": time.time(),
    }
    for i, leaf in enumerate(leaves):
        np.save(os.path.join(tmp, f"leaf_{i:05d}.npy"), np.asarray(leaf))
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)  # atomic commit
    return final


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = [
        int(d.split("_")[1])
        for d in os.listdir(directory)
        if d.startswith("step_") and not d.endswith(".tmp")
        and os.path.exists(os.path.join(directory, d, "manifest.json"))
    ]
    return max(steps) if steps else None


def load_pytree(
    like: Any, directory: str, step: int | None = None, shardings: Any = None
) -> Any:
    """Restore into the structure of ``like``; ``shardings`` (optional
    matching pytree of NamedSharding) re-shards onto the *current* mesh —
    this is the elastic-restart path (checkpoint saved on N hosts, restored
    on M)."""
    step = latest_step(directory) if step is None else step
    if step is None:
        raise FileNotFoundError(f"no checkpoint in {directory}")
    d = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    leaves_like, treedef = jax.tree.flatten(like)
    assert len(leaves_like) == manifest["n_leaves"], "tree structure changed"
    out = []
    shard_leaves = (
        treedef.flatten_up_to(shardings) if shardings is not None else [None] * len(leaves_like)
    )
    for i, (ref, sh) in enumerate(zip(leaves_like, shard_leaves)):
        arr = np.load(os.path.join(d, f"leaf_{i:05d}.npy"))
        if sh is not None:
            out.append(jax.device_put(arr, sh))
        else:
            out.append(jax.device_put(arr))
    return treedef.unflatten(out)


class CheckpointManager:
    """Async writer with keep-k retention and save-every-N policy."""

    def __init__(self, directory: str, keep: int = 3, every_steps: int = 100):
        self.directory = directory
        self.keep = keep
        self.every_steps = every_steps
        self._q: queue.Queue = queue.Queue(maxsize=2)
        self._worker = threading.Thread(target=self._run, daemon=True)
        self._worker.start()
        self._errors: list[Exception] = []

    def maybe_save(self, tree: Any, step: int, force: bool = False) -> bool:
        if not force and (step % self.every_steps != 0):
            return False
        # snapshot to host before enqueueing (donated buffers stay valid)
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)
        self._q.put((host_tree, step))
        return True

    def _run(self) -> None:
        while True:
            item = self._q.get()
            if item is None:
                return
            tree, step = item
            try:
                save_pytree(tree, self.directory, step)
                self._gc()
            except Exception as e:  # surfaced via .check()
                self._errors.append(e)

    def _gc(self) -> None:
        steps = sorted(
            int(d.split("_")[1])
            for d in os.listdir(self.directory)
            if d.startswith("step_") and not d.endswith(".tmp")
        )
        for s in steps[: -self.keep] if self.keep > 0 else []:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"), ignore_errors=True)

    def wait(self) -> None:
        self._q.join() if False else self._drain()

    def _drain(self) -> None:
        while not self._q.empty():
            time.sleep(0.01)
        time.sleep(0.05)  # let the in-flight write commit

    def check(self) -> None:
        if self._errors:
            raise self._errors[0]

    def close(self) -> None:
        self._drain()
        self._q.put(None)
        self._worker.join(timeout=10)
