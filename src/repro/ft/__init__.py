from .checkpoint import CheckpointManager, latest_step, load_pytree, save_pytree
from .elastic import ElasticPlan, StepWatchdog, best_mesh_for, replan

__all__ = ["CheckpointManager", "save_pytree", "load_pytree", "latest_step",
           "StepWatchdog", "best_mesh_for", "replan", "ElasticPlan"]
