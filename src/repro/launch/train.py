"""Production training driver: resumable, checkpointed, watchdogged.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-14b \
        --smoke --steps 50 --ckpt-dir /tmp/ckpt

On this CPU container use --smoke (reduced config); on a cluster the same
driver runs the full config under the production mesh.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_FAMILY, ARCHS
from repro.ft.checkpoint import CheckpointManager, latest_step, load_pytree
from repro.ft.elastic import StepWatchdog
from repro.launch.cells import build_cell


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS, required=True)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    fam = ARCH_FAMILY[args.arch]
    shape = args.shape or {"lm": "train_4k", "gnn": "full_graph_sm",
                           "recsys": "train_batch", "spade": "grab4_stream"}[fam]
    cell = build_cell(args.arch, shape, concrete=True, smoke=args.smoke,
                      seed=args.seed)
    step_fn = jax.jit(cell.fn, donate_argnums=cell.donate)
    state, *rest = cell.args

    mgr = None
    if args.ckpt_dir:
        mgr = CheckpointManager(args.ckpt_dir, keep=3, every_steps=args.ckpt_every)
        if latest_step(args.ckpt_dir) is not None:
            state = load_pytree(state, args.ckpt_dir)
            print(f"resumed from step {int(np.asarray(state.step))}")

    dog = StepWatchdog(factor=5.0)
    start = int(np.asarray(state.step)) if hasattr(state, "step") else 0
    for i in range(start, start + args.steps):
        t0 = time.perf_counter()
        state, metrics = step_fn(state, *rest)
        jax.block_until_ready(metrics["loss"])
        dt = time.perf_counter() - t0
        straggler = dog.observe(i, dt)
        if mgr:
            mgr.maybe_save(state, i + 1)
            mgr.check()
        print(f"step {i + 1} loss={float(metrics['loss']):.4f} "
              f"{dt * 1e3:.0f}ms{' STRAGGLER' if straggler else ''}")
    if mgr:
        mgr.maybe_save(state, start + args.steps, force=True)
        mgr.close()


if __name__ == "__main__":
    main()
