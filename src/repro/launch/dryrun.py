import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS_EXTRA", "")
)

"""Multi-pod dry-run: lower + compile every (architecture x input-shape x
mesh) cell with ShapeDtypeStruct inputs (zero allocation), print
``memory_analysis()`` (proves fit) and ``cost_analysis()`` (feeds
§Roofline), and parse the HLO for collective bytes.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch mixtral-8x7b \
      --shape train_4k --mesh multi
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both \
      --out results/dryrun
"""

import argparse
import json
import re
import time
import traceback

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_FAMILY, ARCHS, Skip, arch_shapes
from repro.dist.sharding import AxisEnv, tree_shardings, use_axis_env
from repro.launch.cells import Cell, build_cell
from repro.launch.mesh import make_production_mesh

# TPU v5e per-chip constants (targets; this container is CPU-only)
PEAK_FLOPS = 197e12  # bf16
HBM_BW = 819e9
ICI_BW = 50e9

_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8, "s32": 4,
    "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1, "c64": 8,
    "c128": 16,
}


def _bytes_of_shape(txt: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(txt):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Sum output-shape bytes of every collective op in the (SPMD-partitioned)
    HLO.  Shapes in the post-SPMD module are per-shard; multiplying by the
    participating device count happens in the roofline (we report per-chip
    link bytes, so per-shard is what we want)."""
    out: dict[str, int] = {c: 0 for c in _COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.strip()
        # match "<name> = <shape> <op>(" forms, e.g. "%ag = bf16[2,4]{...} all-gather("
        for c in _COLLECTIVES:
            # count -start (async) or plain (sync) forms once; skip -done
            # (the wait handle, not a second transfer)
            if re.search(rf"(?:^|\s){c}(?:-start)?\(", s) and f"{c}-done" not in s:
                lhs = s.split("=", 1)
                shape_txt = lhs[1] if len(lhs) > 1 else s
                shape_txt = shape_txt.split(c)[0]
                out[c] += _bytes_of_shape(shape_txt)
                break
    return out


def run_cell(arch: str, shape: str, mesh_kind: str, verbose: bool = True,
             roofline: bool = False, override_layers: int | None = None) -> dict:
    """One (arch, shape, mesh) lowering.  ``roofline=True`` compiles the
    unrolled analysis variant (single-pod only) whose cost_analysis has
    exact trip-count accounting; the default production variant proves
    compilability + memory fit."""
    spec = arch_shapes(arch)[shape]
    if isinstance(spec, Skip):
        return {"arch": arch, "shape": shape, "mesh": mesh_kind,
                "status": "SKIP", "reason": spec.reason}
    t0 = time.time()
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    env = AxisEnv(mesh=mesh)
    try:
        with use_axis_env(env), mesh:
            cell: Cell = build_cell(arch, shape, concrete=False, roofline=roofline,
                                    override_layers=override_layers)
            in_sh = tree_shardings(cell.in_logical)
            jitted = jax.jit(
                cell.fn,
                in_shardings=in_sh,
                donate_argnums=cell.donate,
            )
            lowered = jitted.lower(*cell.args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
            mem = compiled.memory_analysis()
            cost = compiled.cost_analysis()
            if isinstance(cost, (list, tuple)):  # older jax: [dict]
                cost = cost[0] if cost else {}
            hlo = compiled.as_text()
            coll = collective_bytes(hlo)
        n_chips = int(np.prod(mesh.devices.shape))
        # cost_analysis under SPMD reports PER-DEVICE flops/bytes (verified
        # empirically: an 8-way-sharded matmul reports 1/8 of the total);
        # collective bytes parsed from the post-SPMD HLO are per-shard too.
        flops = float(cost.get("flops", 0.0))
        bytes_acc = float(cost.get("bytes accessed", 0.0))
        coll_total = float(sum(coll.values()))
        t_compute = flops / PEAK_FLOPS
        t_memory = bytes_acc / HBM_BW
        t_coll = coll_total / ICI_BW
        dominant = max(
            [("compute", t_compute), ("memory", t_memory), ("collective", t_coll)],
            key=lambda kv: kv[1],
        )[0]
        result = {
            "arch": arch, "shape": shape, "mesh": mesh_kind, "status": "OK",
            "variant": "roofline" if roofline else "production",
            "n_chips": n_chips,
            "flops_per_chip": flops,
            "bytes_per_chip": bytes_acc,
            "collective_bytes_per_chip": coll_total,
            "collectives": coll,
            "t_compute_s": t_compute,
            "t_memory_s": t_memory,
            "t_collective_s": t_coll,
            "dominant": dominant,
            "model_flops": cell.model_flops,
            "useful_flops_ratio": (
                cell.model_flops / (flops * n_chips) if flops > 0 else 0.0
            ),
            "bytes_per_device": getattr(mem, "temp_size_in_bytes", None),
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
            "lower_s": round(t_lower, 1),
            "compile_s": round(t_compile, 1),
            "step_name": cell.step_name,
        }
        if verbose:
            print(f"[{arch} x {shape} x {mesh_kind}] OK "
                  f"compute={t_compute:.3e}s memory={t_memory:.3e}s "
                  f"coll={t_coll:.3e}s dominant={dominant} "
                  f"args={result['argument_bytes']} temp={result['bytes_per_device']} "
                  f"(lower {t_lower:.0f}s compile {t_compile:.0f}s)")
            print(f"  memory_analysis: {mem}")
        return result
    except Exception as e:
        if verbose:
            traceback.print_exc()
        return {"arch": arch, "shape": shape, "mesh": mesh_kind,
                "status": "FAIL", "error": f"{type(e).__name__}: {e}"}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS)
    ap.add_argument("--shape")
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--family", choices=["lm", "gnn", "recsys", "spade"])
    ap.add_argument("--out", default=None, help="directory for per-cell JSON")
    ap.add_argument("--roofline", action="store_true",
                    help="compile the unrolled analysis variant (single-pod)")
    args = ap.parse_args()
    if args.roofline:
        args.mesh = "single"

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    cells: list[tuple[str, str]] = []
    if args.all or args.family:
        for arch in ARCHS:
            if args.family and ARCH_FAMILY[arch] != args.family:
                continue
            for shape in arch_shapes(arch):
                cells.append((arch, shape))
    else:
        if not (args.arch and args.shape):
            ap.error("--arch/--shape or --all/--family required")
        cells.append((args.arch, args.shape))

    failures = 0
    for arch, shape in cells:
        for mk in meshes:
            res = run_cell(arch, shape, mk, roofline=args.roofline)
            if res["status"] == "FAIL":
                failures += 1
                print(f"[{arch} x {shape} x {mk}] FAIL: {res['error']}")
            if args.out:
                os.makedirs(args.out, exist_ok=True)
                suffix = "roofline" if args.roofline else mk
                fn = os.path.join(args.out, f"{arch}__{shape}__{suffix}.json")
                with open(fn, "w") as f:
                    json.dump(res, f, indent=1)
    print(f"dry-run done: {len(cells) * len(meshes)} cells, {failures} failures")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
