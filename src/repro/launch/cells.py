"""Cell matrix: every (architecture x input shape) combination as a
lowerable unit — step function + input pytree (ShapeDtypeStructs for the
dry-run, concrete arrays for smoke/examples) + logical shardings.

A *cell* is what the multi-pod dry-run lowers and compiles, what the
roofline harness analyses, and what the smoke tests execute at reduced
scale.  40 assigned cells + 2 spade cells (the paper's own workload).
"""

from __future__ import annotations

import dataclasses
import functools
from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_FAMILY, Skip, arch_shapes, get_config, get_smoke_config
from repro.configs.base import GNNConfig, LMConfig, RecsysConfig, ShapeSpec, SpadeConfig
from repro.core.incremental import DeviceSpadeState, insert_and_maintain
from repro.core.peel import bulk_peel
from repro.graphstore.structs import DeviceGraph
from repro.models.gnn import GraphBatch, gnn_loss, init_gnn_params, make_triplets
from repro.models.transformer import (
    KVCache,
    cache_window,
    decode_step,
    init_lm_params,
    lm_loss,
    prefill,
)
from repro.models.two_tower import (
    RecsysBatch,
    init_two_tower_params,
    retrieval_scores,
    score_pairs,
    two_tower_loss,
)
from repro.train.optimizer import AdamConfig, TrainState, init_train_state
from repro.train.train_step import make_train_step

__all__ = ["Cell", "build_cell", "MODEL_AXIS"]

MODEL_AXIS = 16  # 'model' mesh axis size in the production meshes

_f32, _bf16, _i32, _b = jnp.float32, jnp.bfloat16, jnp.int32, jnp.bool_


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(int(s) for s in shape), dtype)


def _round_up(x: int, m: int = 512) -> int:
    """Shardable dims are padded to multiples of 512 (covers every mesh
    axis combination: pod*data=32, data*model=256); validity masks make
    padding semantically inert."""
    return -(-int(x) // m) * m


@dataclass
class Cell:
    arch: str
    shape: str
    family: str
    step_name: str
    fn: Callable  # fn(*args)
    args: tuple  # pytree of ShapeDtypeStruct (or concrete arrays)
    in_logical: tuple  # matching pytree of logical-axis tuples
    out_logical: Any  # logical axes for outputs (or None -> unspecified)
    donate: tuple[int, ...] = ()
    model_flops: float = 0.0  # analytic "useful" FLOPs for §Roofline


# ---------------------------------------------------------------------------
# logical-axis rule trees
# ---------------------------------------------------------------------------


def lm_param_logical(cfg: LMConfig, fsdp: bool = True) -> dict:
    F = "fsdp" if fsdp else None
    layers: dict[str, Any] = {
        "attn_norm": (None, None),
        "mlp_norm": (None, None),
        "wq": (None, F, "model"),
        "wk": (None, F, "model"),
        "wv": (None, F, "model"),
        "wo": (None, "model", F),
    }
    if cfg.qk_norm:
        layers["q_norm"] = (None, None)
        layers["k_norm"] = (None, None)
    if cfg.moe:
        if cfg.moe.expert_parallel:
            layers["moe"] = {
                "router": (None, F, None),
                "w_gate": (None, "expert", F, None),
                "w_up": (None, "expert", F, None),
                "w_down": (None, "expert", None, F),
            }
        else:
            layers["moe"] = {
                "router": (None, F, None),
                "w_gate": (None, None, F, "model"),
                "w_up": (None, None, F, "model"),
                "w_down": (None, None, "model", F),
            }
    else:
        layers["mlp"] = {
            "w_gate": (None, F, "model"),
            "w_up": (None, F, "model"),
            "w_down": (None, "model", F),
        }
    return {
        "embed": ("model", F),
        "layers": layers,
        "final_norm": (None,),
        "head": (F, "model"),
    }


def _state_logical(param_logical) -> TrainState:
    return TrainState(
        params=param_logical,
        m=param_logical,
        v=param_logical,
        step=(),
        err=None,
    )


def gnn_param_logical(params) -> Any:
    # GNN params are small: replicated
    return jax.tree.map(lambda p: tuple(None for _ in p.shape), params)


def recsys_param_logical() -> dict:
    rep2 = (None, None)
    mlp = lambda n: {f"w{i}": rep2 for i in range(n)} | {f"b{i}": (None,) for i in range(n)}
    return {
        "user_table": ("rows", None),
        "item_table": ("rows", None),
        "user_mlp": mlp(3),
        "item_mlp": mlp(3),
        "temp": (),
    }


# ---------------------------------------------------------------------------
# LM cells
# ---------------------------------------------------------------------------


def _lm_train_cell(arch, cfg: LMConfig, spec: ShapeSpec, concrete, rng,
                   roofline: bool = False) -> Cell:
    B, S = spec.global_batch, spec.seq_len
    adam = AdamConfig()
    loss = lambda params, batch: lm_loss(params, batch["tokens"], batch["labels"], cfg)
    # microbatched grad accumulation: 8x smaller live activations, and XLA
    # overlaps microbatch k's collectives with k+1's compute.  The roofline
    # variant uses microbatches=1 (identical total FLOPs, no scan).
    micro = 1 if roofline else (8 if B >= 64 else 1)
    step = make_train_step(loss, adam, microbatches=micro)

    def init_fn():
        return init_train_state(init_lm_params(jax.random.PRNGKey(0), cfg))

    if concrete:
        state = init_fn()
        tokens = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), _i32)
        batch = {"tokens": tokens, "labels": tokens}
    else:
        state = jax.eval_shape(init_fn)
        batch = {"tokens": _sds((B, S), _i32), "labels": _sds((B, S), _i32)}

    pl = lm_param_logical(cfg, fsdp=True)
    in_logical = (_state_logical(pl), {"tokens": ("batch", None), "labels": ("batch", None)})
    # 6ND (dense) / 6*N_active*D (MoE) + causal attention term
    n_act = cfg.n_active_params
    attn_flops = 2 * 3 * cfg.n_layers * B * S * S // 2 * cfg.n_heads * cfg.d_head
    mf = 6 * n_act * B * S + attn_flops
    return Cell(arch, spec.name, "lm", "train_step", step, (state, batch), in_logical,
                (_state_logical(pl), None), donate=(0,), model_flops=mf)


def _lm_prefill_cell(arch, cfg: LMConfig, spec: ShapeSpec, concrete, rng) -> Cell:
    B, S = spec.global_batch, spec.seq_len
    fn = functools.partial(prefill, cfg=cfg)
    if concrete:
        params = init_lm_params(jax.random.PRNGKey(0), cfg)
        tokens = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), _i32)
    else:
        params = jax.eval_shape(lambda: init_lm_params(jax.random.PRNGKey(0), cfg))
        tokens = _sds((B, S), _i32)
    pl = lm_param_logical(cfg, fsdp=False)
    cache_logical = KVCache(
        k=(None, "batch", "model", None, None), v=(None, "batch", "model", None, None)
    )
    mf = 2 * cfg.n_active_params * B * S + 2 * 2 * cfg.n_layers * B * S * S // 2 * cfg.n_heads * cfg.d_head
    return Cell(arch, spec.name, "lm", "prefill", fn, (params, tokens),
                (pl, ("batch", None)), (("batch", "model"), cache_logical),
                model_flops=mf)


def _lm_decode_cell(arch, cfg: LMConfig, spec: ShapeSpec, concrete, rng) -> Cell:
    B, S = spec.global_batch, spec.seq_len
    W, _ = cache_window(cfg, S)
    L, Hkv, Dh = cfg.n_layers, cfg.n_kv_heads, cfg.d_head
    fn = functools.partial(decode_step, cfg=cfg)
    dt = jnp.dtype(cfg.dtype)
    if concrete:
        params = init_lm_params(jax.random.PRNGKey(0), cfg)
        cache = KVCache(
            k=jnp.zeros((L, B, W, Hkv, Dh), dt), v=jnp.zeros((L, B, W, Hkv, Dh), dt)
        )
        token = jnp.asarray(rng.integers(0, cfg.vocab, (B,)), _i32)
        pos = jnp.full((B,), min(S - 1, W + 3), _i32)
    else:
        params = jax.eval_shape(lambda: init_lm_params(jax.random.PRNGKey(0), cfg))
        cache = KVCache(k=_sds((L, B, W, Hkv, Dh), dt), v=_sds((L, B, W, Hkv, Dh), dt))
        token = _sds((B,), _i32)
        pos = _sds((B,), _i32)
    pl = lm_param_logical(cfg, fsdp=False)
    b_ax = "batch" if B % 32 == 0 else None
    # GQA kv-heads (8) don't divide the model axis (16): shard the cache's
    # sequence dim instead (flash-decode style) — softmax over W becomes a
    # partial-reduce + all-reduce, which GSPMD emits automatically.
    cl = KVCache(k=(None, b_ax, "model", None, None), v=(None, b_ax, "model", None, None))
    mf = 2 * cfg.n_active_params * B + 2 * 2 * L * B * W * cfg.n_heads * Dh
    return Cell(arch, spec.name, "lm", "decode_step", fn, (params, cache, token, pos),
                (pl, cl, (b_ax,), (b_ax,)), ((b_ax, "model"), cl),
                donate=(1,), model_flops=mf)


# ---------------------------------------------------------------------------
# GNN cells
# ---------------------------------------------------------------------------


def _graph_batch(cfg: GNNConfig, spec: ShapeSpec, concrete, rng) -> tuple[GraphBatch, int]:
    """Build the fixed-shape GraphBatch for a shape spec."""
    if spec.kind == "graph_mini":
        # sampled block caps: seeds + fanout-expansion worst case
        seeds = spec.batch_nodes
        e1 = seeds * spec.fanout[0]
        e2 = e1 * spec.fanout[1] if len(spec.fanout) > 1 else 0
        E = e1 + e2
        N = seeds + E  # every sampled edge can introduce a new node
    elif spec.kind == "graph_batch":
        N = spec.n_nodes * spec.n_graphs
        E = spec.n_edges * spec.n_graphs
    else:
        N, E = spec.n_nodes, spec.n_edges
    N, E = _round_up(N), _round_up(E)
    F = spec.d_feat if spec.d_feat else cfg.d_feat
    T = E * cfg.triplet_cap_per_edge if cfg.kind == "dimenet" else 512
    Fe = 4 if cfg.kind == "meshgraphnet" else 0

    if not concrete:
        g = GraphBatch(
            node_feat=_sds((N, F), _f32),
            edge_src=_sds((E,), _i32),
            edge_dst=_sds((E,), _i32),
            edge_mask=_sds((E,), _b),
            node_mask=_sds((N,), _b),
            edge_feat=_sds((E, Fe), _f32),
            labels=_sds((N,), _i32),
            tri_in=_sds((T,), _i32),
            tri_out=_sds((T,), _i32),
            tri_angle=_sds((T,), _f32),
            tri_mask=_sds((T,), _b),
            edge_len=_sds((E,), _f32),
        )
        return g, F

    src = rng.integers(0, N, E).astype(np.int32)
    dst = rng.integers(0, N, E).astype(np.int32)
    if cfg.kind == "dimenet":
        ti, to, tm = make_triplets(src, dst, cfg.triplet_cap_per_edge, rng)
    else:
        ti = to = np.zeros(1, np.int32)
        tm = np.zeros(1, bool)
    g = GraphBatch(
        node_feat=jnp.asarray(rng.normal(size=(N, F)).astype(np.float32)),
        edge_src=jnp.asarray(src),
        edge_dst=jnp.asarray(dst),
        edge_mask=jnp.ones(E, bool),
        node_mask=jnp.ones(N, bool),
        edge_feat=jnp.asarray(rng.normal(size=(E, Fe)).astype(np.float32)),
        labels=jnp.asarray(rng.integers(0, cfg.n_classes, N).astype(np.int32)),
        tri_in=jnp.asarray(ti),
        tri_out=jnp.asarray(to),
        tri_angle=jnp.asarray(
            rng.uniform(0, np.pi, ti.shape[0]).astype(np.float32)
        ),
        tri_mask=jnp.asarray(tm),
        edge_len=jnp.asarray(rng.uniform(0.5, 4.0, E).astype(np.float32)),
    )
    return g, F


def _gnn_graph_logical(g: GraphBatch) -> GraphBatch:
    return GraphBatch(
        node_feat=("vertex", None),
        edge_src=("edges",),
        edge_dst=("edges",),
        edge_mask=("edges",),
        node_mask=("vertex",),
        edge_feat=("edges", None),
        labels=("vertex",),
        tri_in=("edges",),
        tri_out=("edges",),
        tri_angle=("edges",),
        tri_mask=("edges",),
        edge_len=("edges",),
    )


def _gnn_train_cell(arch, cfg: GNNConfig, spec: ShapeSpec, concrete, rng) -> Cell:
    g, F = _graph_batch(cfg, spec, concrete, rng)
    adam = AdamConfig(weight_decay=0.0)
    loss = lambda params, batch: gnn_loss(params, batch, cfg)
    step = make_train_step(loss, adam)

    def init_fn():
        return init_train_state(init_gnn_params(jax.random.PRNGKey(0), cfg, F))

    state = init_fn() if concrete else jax.eval_shape(init_fn)
    params_shapes = jax.eval_shape(lambda: init_gnn_params(jax.random.PRNGKey(0), cfg, F))
    pl = gnn_param_logical(params_shapes)
    in_logical = (_state_logical(pl), _gnn_graph_logical(g))
    E = g.edge_src.shape[0]
    N = g.node_feat.shape[0]
    mf = _gnn_model_flops(cfg, N, E, F) * 3.0  # fwd + bwd(2x)
    return Cell(arch, spec.name, "gnn", "train_step", step, (state, g), in_logical,
                (_state_logical(pl), None), donate=(0,), model_flops=float(mf))


def _gnn_model_flops(cfg: GNNConfig, N: int, E: int, F: int) -> float:
    """Analytic forward FLOPs (matmul-dominated terms; 2 flops/MAC)."""
    H, L, C = cfg.d_hidden, cfg.n_layers, cfg.n_classes
    if cfg.kind == "gcn":
        dims = [F] + [H] * (L - 1) + [C]
        fl = sum(2 * N * a * b + 4 * E * b for a, b in zip(dims[:-1], dims[1:]))
        return float(fl)
    if cfg.kind == "gat":
        hds = cfg.n_heads
        fl = 0
        d_in = F
        for li in range(L):
            d_out = C if li == L - 1 else H
            fl += 2 * N * d_in * hds * d_out  # projection
            fl += 6 * E * hds * d_out  # scores + weighted messages
            d_in = d_out if li == L - 1 else hds * d_out
        return float(fl)
    if cfg.kind == "meshgraphnet":
        n_mlp = cfg.mlp_layers
        enc = 2 * N * (F * H + (n_mlp - 1) * H * H) + 2 * E * (4 * H + (n_mlp - 1) * H * H)
        per_step = 2 * E * (3 * H * H + (n_mlp - 1) * H * H) + 2 * N * (
            2 * H * H + (n_mlp - 1) * H * H
        )
        dec = 2 * N * (H * H * (n_mlp - 1) + H * C)
        return float(enc + L * per_step + dec)
    # dimenet
    T = E * cfg.triplet_cap_per_edge
    B_, ns, nr, nb = L, cfg.n_spherical, cfg.n_radial, cfg.n_bilinear
    per_block = (
        2 * T * (ns * nr) * nb  # sbf basis projection
        + 2 * T * nb * H * H  # bilinear interaction
        + 2 * T * H  # msg gather mult
        + 2 * E * H * H * 3  # msg/out transforms
    )
    embed = 2 * N * F * H + 2 * E * nr * H
    return float(embed + B_ * per_block)


# ---------------------------------------------------------------------------
# recsys cells
# ---------------------------------------------------------------------------


def _recsys_batch(cfg: RecsysConfig, B, concrete, rng) -> RecsysBatch:
    Fu, Fi, M = cfg.n_user_fields, cfg.n_item_fields, cfg.multi_hot
    if not concrete:
        return RecsysBatch(
            user_idx=_sds((B, Fu, M), _i32),
            user_wt=_sds((B, Fu, M), _f32),
            item_idx=_sds((B, Fi, M), _i32),
            item_wt=_sds((B, Fi, M), _f32),
            log_q=_sds((B,), _f32),
        )
    return RecsysBatch(
        user_idx=jnp.asarray(rng.integers(0, cfg.user_vocab, (B, Fu, M)), _i32),
        user_wt=jnp.ones((B, Fu, M), _f32),
        item_idx=jnp.asarray(rng.integers(0, cfg.item_vocab, (B, Fi, M)), _i32),
        item_wt=jnp.ones((B, Fi, M), _f32),
        log_q=jnp.zeros((B,), _f32),
    )


_RB_LOGICAL = RecsysBatch(
    user_idx=("batch", None, None),
    user_wt=("batch", None, None),
    item_idx=("batch", None, None),
    item_wt=("batch", None, None),
    log_q=("batch",),
)


def _recsys_cell(arch, cfg: RecsysConfig, spec: ShapeSpec, concrete, rng) -> Cell:
    pl = recsys_param_logical()

    def init_fn():
        return init_two_tower_params(jax.random.PRNGKey(0), cfg)

    if spec.kind == "recsys_train":
        adam = AdamConfig(weight_decay=0.0)
        loss = lambda params, batch: two_tower_loss(params, batch, cfg)
        step = make_train_step(loss, adam)
        if concrete:
            state = init_train_state(init_fn())
        else:
            state = jax.eval_shape(lambda: init_train_state(init_fn()))
        batch = _recsys_batch(cfg, spec.batch, concrete, rng)
        B = spec.batch
        mf = (_recsys_flops(cfg, B) + 2.0 * B * B * cfg.tower_mlp[-1]) * 3
        return Cell(arch, spec.name, "recsys", "train_step", step, (state, batch),
                    (_state_logical(pl), _RB_LOGICAL), (_state_logical(pl), None),
                    donate=(0,), model_flops=mf)
    if spec.kind == "recsys_serve":
        fn = functools.partial(score_pairs, cfg=cfg)
        params = init_fn() if concrete else jax.eval_shape(init_fn)
        batch = _recsys_batch(cfg, spec.batch, concrete, rng)
        return Cell(arch, spec.name, "recsys", "score_pairs", fn, (params, batch),
                    (pl, _RB_LOGICAL), ("batch",), model_flops=_recsys_flops(cfg, spec.batch))
    # retrieval: one query vs n_candidates precomputed item embeddings
    fn = functools.partial(retrieval_scores, cfg=cfg, top_k=100)
    params = init_fn() if concrete else jax.eval_shape(init_fn)
    Fu, M, D = cfg.n_user_fields, cfg.multi_hot, cfg.embed_dim
    N = _round_up(spec.n_candidates)
    if concrete:
        uidx = jnp.asarray(rng.integers(0, cfg.user_vocab, (1, Fu, M)), _i32)
        uwt = jnp.ones((1, Fu, M), _f32)
        cand = jnp.asarray(rng.normal(size=(N, D)).astype(np.float32))
    else:
        uidx, uwt = _sds((1, Fu, M), _i32), _sds((1, Fu, M), _f32)
        cand = _sds((N, D), _f32)
    mf = 2.0 * N * D
    return Cell(arch, spec.name, "recsys", "retrieval", fn,
                (params, uidx, uwt, cand),
                (pl, (None, None, None), (None, None, None), ("rows", None)),
                None, model_flops=mf)


def _recsys_flops(cfg: RecsysConfig, B: int) -> float:
    D = cfg.embed_dim
    lookups = (cfg.n_user_fields + cfg.n_item_fields) * cfg.multi_hot * D
    dims_u = [cfg.n_user_fields * D, *cfg.tower_mlp]
    dims_i = [cfg.n_item_fields * D, *cfg.tower_mlp]
    mlp = sum(a * b for a, b in zip(dims_u[:-1], dims_u[1:])) + sum(
        a * b for a, b in zip(dims_i[:-1], dims_i[1:])
    )
    return float(B) * (2.0 * mlp + lookups)


# ---------------------------------------------------------------------------
# spade cells (the paper's own workload)
# ---------------------------------------------------------------------------


def _spade_graph(cfg: SpadeConfig, concrete, rng, n=None, e=None) -> DeviceGraph:
    N = _round_up(n or cfg.n_capacity)
    E = _round_up(e or cfg.e_capacity)
    if not concrete:
        return DeviceGraph(
            src=_sds((E,), _i32), dst=_sds((E,), _i32), c=_sds((E,), _f32),
            edge_mask=_sds((E,), _b), a=_sds((N,), _f32), vertex_mask=_sds((N,), _b),
            n_capacity=N, e_capacity=E,
        )
    from repro.graphstore.structs import device_graph_from_coo

    m = int(E * 0.9)
    src = rng.integers(0, N, m)
    dst = rng.integers(0, N, m)
    keep = src != dst
    return device_graph_from_coo(
        N, src[keep], dst[keep], np.ones(keep.sum(), np.float32),
        n_capacity=N, e_capacity=E,
    )


_DG_LOGICAL = dict(
    src=("edges",), dst=("edges",), c=("edges",), edge_mask=("edges",),
    a=(None,), vertex_mask=(None,),
)


def _spade_cells(arch, cfg: SpadeConfig, spec: ShapeSpec, concrete, rng,
                 unroll: bool = False) -> Cell:
    Ncap, Ecap = _round_up(cfg.n_capacity), _round_up(cfg.e_capacity)
    gl = DeviceGraph(
        n_capacity=Ncap, e_capacity=Ecap, **{k: v for k, v in _DG_LOGICAL.items()}
    )
    # essential per-round work: 2 segment-sum adds + 2 mask mults per edge,
    # plus threshold compare/update over vertices
    E, R = Ecap, cfg.max_rounds
    mf = float(R) * (6.0 * E + 4.0 * Ncap)
    if spec.kind == "spade_static":
        fn = functools.partial(bulk_peel, eps=cfg.eps, max_rounds=cfg.max_rounds,
                               unroll=unroll)
        g = _spade_graph(cfg, concrete, rng)
        return Cell(arch, spec.name, "spade", "bulk_peel", fn, (g,),
                    (gl,), None, model_flops=mf)
    # streaming maintenance cell
    fn = functools.partial(insert_and_maintain, eps=cfg.eps, max_rounds=cfg.max_rounds,
                           unroll=unroll)
    B = cfg.batch_edges
    if concrete:
        g = _spade_graph(cfg, True, rng)
        from repro.core.incremental import init_state

        state = init_state(g, eps=cfg.eps)
        bs = jnp.asarray(rng.integers(0, g.n_capacity, B), _i32)
        bd = jnp.asarray(rng.integers(0, g.n_capacity, B), _i32)
        bc = jnp.ones((B,), _f32)
        valid = bs != bd
    else:
        g = _spade_graph(cfg, False, rng)
        state = DeviceSpadeState(
            graph=g, level=_sds((g.n_capacity,), _i32), best_g=_sds((), _f32),
            community=_sds((g.n_capacity,), _b), edge_count=_sds((), _i32),
            w0=_sds((g.n_capacity,), _f32),
        )
        bs = bd = _sds((B,), _i32)
        bc = _sds((B,), _f32)
        valid = _sds((B,), _b)
    sl = DeviceSpadeState(graph=gl, level=(None,), best_g=(), community=(None,),
                          edge_count=(), w0=(None,))
    return Cell(arch, spec.name, "spade", "insert_and_maintain", fn,
                (state, bs, bd, bc, valid),
                (sl, (None,), (None,), (None,), (None,)), sl,
                donate=(0,), model_flops=mf)


# ---------------------------------------------------------------------------
# public entry
# ---------------------------------------------------------------------------


def build_cell(arch: str, shape: str, *, concrete: bool = False, smoke: bool = False,
               roofline: bool = False, override_layers: int | None = None,
               seed: int = 0) -> Cell | Skip:
    """Build one cell.  ``smoke=True`` swaps in the reduced config and
    shrinks the shape spec to CPU scale (same code path, tiny sizes).

    ``roofline=True`` builds the *analysis* variant: scans python-unrolled
    (XLA cost_analysis counts while bodies once — DESIGN.md §7), coarse
    attention blocks to bound HLO size, microbatches=1 (identical total
    FLOPs).  Never executed; memory numbers come from the production
    variant."""
    fam = ARCH_FAMILY[arch]
    spec = arch_shapes(arch)[shape]
    if isinstance(spec, Skip):
        return spec
    cfg = get_smoke_config(arch) if smoke else get_config(arch)
    if smoke:
        spec = _shrink(spec)
    if roofline:
        if fam == "lm":
            qb = max(spec.seq_len // 4, 128) if spec.seq_len else 512
            cfg = dataclasses.replace(cfg, unroll=True, q_block=qb, kv_block=qb)
        elif fam == "gnn":
            cfg = dataclasses.replace(cfg, unroll=True)
    if override_layers is not None and hasattr(cfg, "n_layers"):
        cfg = dataclasses.replace(cfg, n_layers=override_layers)
    rng = np.random.default_rng(seed)
    if fam == "lm":
        if spec.kind == "train":
            return _lm_train_cell(arch, cfg, spec, concrete, rng, roofline=roofline)
        if spec.kind == "prefill":
            return _lm_prefill_cell(arch, cfg, spec, concrete, rng)
        return _lm_decode_cell(arch, cfg, spec, concrete, rng)
    if fam == "gnn":
        return _gnn_train_cell(arch, cfg, spec, concrete, rng)
    if fam == "recsys":
        return _recsys_cell(arch, cfg, spec, concrete, rng)
    if fam == "spade":
        return _spade_cells(arch, cfg, spec, concrete, rng, unroll=roofline)
    raise KeyError(arch)


def _shrink(spec: ShapeSpec) -> ShapeSpec:
    """CPU-scale version of a shape spec (same kind, tiny sizes)."""
    reps = {}
    if spec.seq_len:
        reps["seq_len"] = min(spec.seq_len, 64)
    if spec.global_batch:
        reps["global_batch"] = min(spec.global_batch, 2)
    if spec.n_nodes:
        reps["n_nodes"] = min(spec.n_nodes, 64)
    if spec.n_edges:
        reps["n_edges"] = min(spec.n_edges, 256)
    if spec.batch_nodes:
        reps["batch_nodes"] = min(spec.batch_nodes, 8)
    if spec.fanout:
        reps["fanout"] = tuple(min(f, 3) for f in spec.fanout)
    if spec.n_graphs:
        reps["n_graphs"] = min(spec.n_graphs, 4)
    if spec.d_feat:
        reps["d_feat"] = min(spec.d_feat, 8)
    if spec.batch:
        reps["batch"] = min(spec.batch, 4)
    if spec.n_candidates:
        reps["n_candidates"] = min(spec.n_candidates, 128)
    return dataclasses.replace(spec, **reps)
