"""Production meshes.

Single pod: (data=16, model=16) = 256 chips (TPU v5e pod slice).
Multi-pod:  (pod=2, data=16, model=16) = 512 chips; the 'pod' axis carries
pure data parallelism (params replicated across pods, FSDP within a pod)
so the slow inter-pod (DCN) hop only sees gradient all-reduces.

Defined as functions (never module-level constants) so importing this
module never touches jax device state.
"""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "SINGLE_POD", "MULTI_POD"]

SINGLE_POD = (16, 16)
MULTI_POD = (2, 16, 16)


def make_production_mesh(*, multi_pod: bool = False):
    shape = MULTI_POD if multi_pod else SINGLE_POD
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Whatever fits the current host (smoke tests, examples)."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"))
