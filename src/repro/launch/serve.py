"""Streaming fraud-detection serving driver (the paper's deployment):

    PYTHONPATH=src python -m repro.launch.serve --metric FD --edges 5000 \
        --batch 100 --grouping
"""

from __future__ import annotations

import argparse

from repro.graphstore.generators import make_transaction_stream
from repro.serve.service import run_service


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--metric", choices=["DG", "DW", "FD"], default="DW")
    ap.add_argument("--vertices", type=int, default=20000)
    ap.add_argument("--edges", type=int, default=80000)
    ap.add_argument("--batch", type=int, default=1)
    ap.add_argument("--grouping", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    stream = make_transaction_stream(n=args.vertices, m=args.edges, seed=args.seed)
    rep = run_service(stream, metric=args.metric, edge_grouping=args.grouping,
                      batch_size=args.batch)
    print(f"edges={rep.n_edges} reorders={rep.n_reorders} "
          f"us/edge={rep.mean_us_per_edge:.1f} recall={rep.fraud_recall:.2f} "
          f"prevention={rep.prevention_ratio} latency_s={rep.detection_latency_s}")


if __name__ == "__main__":
    main()
