"""Streaming fraud-detection serving driver (the paper's deployment),
routed through the :class:`repro.serve.SpadeService` facade — every plane
is reachable from the CLI:

    # host oracle (exact per-edge reorders, edge grouping)
    PYTHONPATH=src python -m repro.launch.serve --plane host \
        --semantics FD --edges 5000 --batch 100 --grouping

    # device plane, sliding window + predictive workset engine
    PYTHONPATH=src python -m repro.launch.serve --semantics DW \
        --batch 512 --window 8 --workset --refresh-every 32

    # mesh-sharded (force host devices on CPU):
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python -m repro.launch.serve --mesh 8 --batch 512
"""

from __future__ import annotations

import argparse

from repro.core.semantics import available
from repro.graphstore.generators import make_transaction_stream
from repro.serve import EngineSpec, SpadeService


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--semantics", "--metric", dest="semantics",
                    choices=list(available()), default="DW",
                    help="registered suspiciousness semantics "
                         "(--metric is the deprecated alias)")
    ap.add_argument("--plane", choices=["device", "host"], default="device")
    ap.add_argument("--vertices", type=int, default=20000)
    ap.add_argument("--edges", type=int, default=80000)
    ap.add_argument("--batch", type=int, default=0,
                    help="edges per tick (0: plane default — 1 on host, "
                         "1024 on device)")
    ap.add_argument("--grouping", action="store_true",
                    help="host plane: benign/urgent edge grouping")
    ap.add_argument("--mesh", type=int, default=0,
                    help="shard edge buffers over N devices (device plane)")
    ap.add_argument("--window", type=int, default=0,
                    help="sliding window depth in ticks (device plane)")
    ap.add_argument("--workset", action="store_true",
                    help="affected-area workset engine (device plane)")
    ap.add_argument("--no-predictive", action="store_true",
                    help="workset: synced-scalar bucket selection instead "
                         "of the predictive selector")
    ap.add_argument("--refresh-every", type=int, default=0)
    ap.add_argument("--max-rounds", type=int, default=20)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    stream = make_transaction_stream(n=args.vertices, m=args.edges,
                                     seed=args.seed)
    if args.plane == "host":
        device_flags = [name for name, on in [
            ("--mesh", args.mesh), ("--window", args.window),
            ("--workset", args.workset),
            ("--no-predictive", args.no_predictive),
            ("--refresh-every", args.refresh_every),
        ] if on]
        if device_flags:
            ap.error(f"{', '.join(device_flags)} require --plane device")
        spec = EngineSpec(
            plane="host",
            grouping=args.grouping,
            batch_edges=args.batch or None,
        )
    else:
        mesh = None
        if args.mesh:
            import jax

            mesh = jax.make_mesh((args.mesh,), ("data",))
        spec = EngineSpec(
            plane="device",
            mesh=mesh,
            batch_edges=args.batch or None,
            window_ticks=args.window,
            workset=args.workset,
            predictive=not args.no_predictive,
            refresh_every=args.refresh_every,
            max_rounds=args.max_rounds,
        )
    rep = SpadeService(semantics=args.semantics, spec=spec).run(stream)
    if args.plane == "host":
        print(f"edges={rep.n_edges} reorders={rep.n_reorders} "
              f"us/edge={rep.mean_us_per_edge:.1f} "
              f"recall={rep.fraud_recall:.2f} "
              f"prevention={rep.prevention_ratio} "
              f"latency_s={rep.detection_latency_s}")
    else:
        print(f"edges={rep.n_edges} ticks={rep.n_ticks} "
              f"us/edge={rep.mean_us_per_edge:.1f} "
              f"recall={rep.fraud_recall:.2f} g={rep.final_g:.1f} "
              f"live={rep.live_edges} "
              f"ws/fb={rep.n_workset_ticks}/{rep.n_fallback_ticks} "
              f"pred/miss={rep.n_predicted_ticks}/{rep.n_bucket_miss_ticks}")


if __name__ == "__main__":
    main()
