"""Data pipeline: synthetic token batches, transaction streams (see
repro.graphstore.generators), graph batch builders (see repro.launch.cells)."""
