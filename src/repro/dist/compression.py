"""Error-feedback int8 gradient compression (the dist plane's wire format).

Gradient all-reduces across the slow inter-pod hop move 4 bytes/param per
step; quantizing to int8 with a per-tensor scale cuts that 4x.  Naive
quantization biases training, so the quantization residual is carried in
an *error-feedback* buffer and re-injected before the next quantization
(EF-SGD / 1-bit Adam argument): the accumulated dequantized signal tracks
the accumulated true signal to within one quantum, so convergence is
preserved.

    deq, err' = EF(g, err):   x = g + err
                              q = round(x / s) in int8,  s = max|x| / 127
                              deq = q * s;   err' = x - deq
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

__all__ = ["quantize_int8", "dequantize_int8", "compress_grads", "ef_compress_tree"]


def quantize_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor int8 quantization: returns (q int8, scale f32)."""
    x = x.astype(jnp.float32)
    scale = jnp.max(jnp.abs(x)) / 127.0
    safe = jnp.where(scale > 0.0, scale, 1.0)
    q = jnp.clip(jnp.round(x / safe), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_grads(g: jax.Array, err: jax.Array) -> tuple[jax.Array, jax.Array]:
    """One EF compression step on a single tensor.

    Returns ``(deq, err')``: the dequantized gradient (what the wire would
    deliver) in ``g``'s dtype and the updated residual (f32).
    """
    x = g.astype(jnp.float32) + err.astype(jnp.float32)
    q, scale = quantize_int8(x)
    deq = dequantize_int8(q, scale)
    return deq.astype(g.dtype), x - deq


def ef_compress_tree(grads: Any, err: Any = None) -> tuple[Any, Any]:
    """EF compression over a gradient pytree.

    ``err`` must match ``grads``' structure (or None to start from zero
    residuals).  Returns ``(deq_tree, err_tree)``.
    """
    leaves_g, treedef = jax.tree.flatten(grads)
    if err is None:
        leaves_e = [jnp.zeros(g.shape, jnp.float32) for g in leaves_g]
    else:
        leaves_e = treedef.flatten_up_to(err)
    out = [compress_grads(g, e) for g, e in zip(leaves_g, leaves_e)]
    return (
        treedef.unflatten([o[0] for o in out]),
        treedef.unflatten([o[1] for o in out]),
    )
