"""repro.dist — the distribution plane.

* :mod:`repro.dist.sharding` — logical-axis layer: ``AxisEnv`` maps
  logical names ("batch", "edges", ...) onto physical mesh axes;
  ``constrain`` / ``tree_shardings`` lower annotations to GSPMD.
* :mod:`repro.dist.compression` — error-feedback int8 gradient
  compression for the slow inter-pod gradient all-reduce.
* :mod:`repro.dist.graph` — mesh-sharded incremental peeling: edge
  buffers partitioned along a data axis, replicated vertex state, one
  fused psum per peel round.
"""

from repro.dist.compression import compress_grads, ef_compress_tree
from repro.dist.graph import (
    init_sharded_state,
    shard_graph,
    sharded_bulk_peel,
    sharded_bulk_peel_warm,
    sharded_bulk_peel_warm_workset,
    sharded_delete_and_maintain,
    sharded_full_refresh,
    sharded_insert_and_maintain,
    sharded_insert_and_maintain_auto,
    sharded_peel_weights,
    sharded_slide_and_maintain,
    sharded_slide_and_maintain_auto,
)
from repro.dist.sharding import (
    AxisEnv,
    axis_env,
    constrain,
    tree_shardings,
    use_axis_env,
)

__all__ = [
    "AxisEnv",
    "axis_env",
    "constrain",
    "tree_shardings",
    "use_axis_env",
    "compress_grads",
    "ef_compress_tree",
    "shard_graph",
    "sharded_peel_weights",
    "sharded_bulk_peel",
    "sharded_bulk_peel_warm",
    "sharded_bulk_peel_warm_workset",
    "init_sharded_state",
    "sharded_insert_and_maintain",
    "sharded_insert_and_maintain_auto",
    "sharded_delete_and_maintain",
    "sharded_slide_and_maintain",
    "sharded_slide_and_maintain_auto",
    "sharded_full_refresh",
]
