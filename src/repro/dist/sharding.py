"""Logical-axis sharding layer: the dist plane's naming contract.

Model and engine code annotates arrays with *logical* axis names
("batch", "edges", "model", ...) and stays mesh-agnostic.  An
:class:`AxisEnv` installed around jit lowering (``use_axis_env``) maps
logical names onto whatever physical mesh axes actually exist; outside
any env — unit tests, a single device — every annotation is a no-op, so
the same model code runs unmodified from a laptop to a multi-pod mesh.

Resolution drops mesh axes that are absent from the current mesh (e.g.
``"batch" -> ("pod", "data")`` becomes plain ``"data"`` on a single-pod
mesh), and :func:`constrain` additionally drops a constraint whose dim
is not divisible by the resolved axis sizes, so smoke-scale shapes lower
cleanly under a production-shaped mesh.
"""

from __future__ import annotations

import dataclasses
import math
from contextlib import contextmanager
from typing import Any, Iterator, Mapping, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = [
    "DEFAULT_RULES",
    "AxisEnv",
    "use_axis_env",
    "axis_env",
    "constrain",
    "tree_shardings",
]

# logical axis -> mesh axes that may carry it, in order; axes absent from
# the active mesh drop out at resolution time.
DEFAULT_RULES: dict[str, tuple[str, ...]] = {
    "batch": ("pod", "data"),  # pure data parallelism (DCN-friendly)
    "fsdp": ("data",),  # param/optimizer shards within a pod
    "model": ("model",),  # tensor parallelism
    "expert": ("model",),  # expert parallelism rides the model axis
    "seq": ("model",),  # sequence-sharded serving attention
    "vertex": ("model",),  # GNN vertex arrays
    "edges": ("pod", "data"),  # COO edge buffers (spade + GNN)
    "rows": ("data", "model"),  # embedding-table rows
    "data": ("data",),  # escape hatch: name the mesh axis directly
}


@dataclasses.dataclass(frozen=True)
class AxisEnv:
    """A mesh plus the logical->mesh-axis rule table.

    ``rules`` entries are merged over :data:`DEFAULT_RULES`; map a logical
    name to ``()`` to force replication of that axis.
    """

    mesh: Mesh | None = None
    rules: Mapping[str, Sequence[str]] | None = None

    def rule(self, logical: str) -> tuple[str, ...]:
        if self.rules is not None and logical in self.rules:
            return tuple(self.rules[logical])
        try:
            return DEFAULT_RULES[logical]
        except KeyError:
            raise KeyError(
                f"unknown logical axis {logical!r}; known: "
                f"{sorted(set(DEFAULT_RULES) | set(self.rules or ()))}"
            ) from None

    def resolve(self, logical: str | None) -> str | tuple[str, ...] | None:
        """Mesh axes carrying ``logical`` on this mesh (None if none do)."""
        if logical is None or self.mesh is None:
            return None
        axes = tuple(a for a in self.rule(logical) if a in self.mesh.shape)
        if not axes:
            return None
        return axes[0] if len(axes) == 1 else axes

    def axis_size(self, logical: str | None) -> int:
        """Total number of shards ``logical`` resolves to (1 if replicated)."""
        ax = self.resolve(logical)
        if ax is None:
            return 1
        axes = (ax,) if isinstance(ax, str) else ax
        return math.prod(self.mesh.shape[a] for a in axes)

    def spec(self, *logical: str | None) -> P:
        return P(*(self.resolve(l) for l in logical))

    def sharding(self, *logical: str | None) -> NamedSharding:
        if self.mesh is None:
            raise ValueError("AxisEnv has no mesh; cannot build a NamedSharding")
        return NamedSharding(self.mesh, self.spec(*logical))


_STACK: list[AxisEnv] = []


def axis_env() -> AxisEnv | None:
    """The innermost active AxisEnv, or None outside any ``use_axis_env``."""
    return _STACK[-1] if _STACK else None


@contextmanager
def use_axis_env(env: AxisEnv) -> Iterator[AxisEnv]:
    _STACK.append(env)
    try:
        yield env
    finally:
        _STACK.pop()


def constrain(x: jax.Array, *logical: str | None) -> jax.Array:
    """Annotate ``x`` with logical axes (one per dim, None = unconstrained).

    Lowers to ``jax.lax.with_sharding_constraint`` under an active mesh
    env; a no-op otherwise.  Dims not divisible by the resolved shard
    count keep their data but lose the constraint (replicated).
    """
    env = axis_env()
    if env is None or env.mesh is None:
        return x
    if len(logical) != x.ndim:
        raise ValueError(
            f"constrain got {len(logical)} logical axes for rank-{x.ndim} array"
        )
    spec = []
    for dim, name in zip(x.shape, logical):
        ax = env.resolve(name)
        if ax is not None and dim % env.axis_size(name) != 0:
            ax = None
        spec.append(ax)
    return jax.lax.with_sharding_constraint(x, NamedSharding(env.mesh, P(*spec)))


def _is_logical_leaf(node: Any) -> bool:
    """A tuple of logical names / Nones (possibly empty -> scalar)."""
    return isinstance(node, tuple) and all(
        isinstance(e, (str, type(None))) for e in node
    )


def tree_shardings(logical_tree: Any, env: AxisEnv | None = None) -> Any:
    """Map a pytree of logical-axis tuples to a matching NamedSharding tree.

    Leaves are tuples like ``("batch", None)`` (``()`` for scalars); the
    result plugs straight into ``jax.jit(in_shardings=...)``.
    """
    env = env if env is not None else axis_env()
    if env is None or env.mesh is None:
        raise ValueError("tree_shardings requires an active AxisEnv with a mesh "
                         "(wrap the call in use_axis_env)")
    return jax.tree.map(
        lambda leaf: env.sharding(*leaf), logical_tree, is_leaf=_is_logical_leaf
    )
