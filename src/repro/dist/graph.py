"""Mesh-sharded incremental peeling: the dist plane's graph engine.

Dupin-style edge partitioning: a :class:`DeviceGraph`'s COO edge buffers
are block-partitioned along one mesh axis while every vertex array stays
replicated.  Each bulk-peel round is then embarrassingly parallel — every
shard segment-sums the suspiciousness its *local* edges contribute to
each vertex — followed by one ``psum`` that recovers the global
per-vertex weight deltas (plus the scalar f/edge-loss terms, fused into
the same all-reduce).  Thresholds, peel masks and the detected community
are computed from the psum'd (replicated) quantities, so every shard
takes the identical round sequence and the result matches single-device
:func:`repro.core.peel.bulk_peel` exactly for order-robust weights
(integer-valued suspiciousness sums are exact in f32) and up to
reduction-order rounding otherwise.  The 2(1+eps) guarantee carries over
unchanged: the sharded round computes the same generalized peeling step,
only the reduction is distributed.

Capacity growth stays a host-side reallocation; edge *insertion* is a
device-side sharded scatter: the batch is replicated, each shard claims
the global slot range it owns (``edge_count`` is a replicated scalar) and
writes only the batch entries that land in its block.

Entry points mirror the single-device engine one-for-one:

=============================  ========================================
single device                  sharded (``mesh=``, ``axis=``)
=============================  ========================================
``bulk_peel``                  ``sharded_bulk_peel``
``bulk_peel_warm``             ``sharded_bulk_peel_warm``
``bulk_peel_warm_workset``     ``sharded_bulk_peel_warm_workset``
``DeviceGraph.peel_weights``   ``sharded_peel_weights``
``init_state``                 ``init_sharded_state``
``insert_and_maintain``        ``sharded_insert_and_maintain``
``insert_and_maintain_auto``   ``sharded_insert_and_maintain_auto``
``insert_..._predictive``      ``sharded_insert_and_maintain_predictive``
``delete_and_maintain``        ``sharded_delete_and_maintain``
``slide_and_maintain``         ``sharded_slide_and_maintain``
``slide_and_maintain_auto``    ``sharded_slide_and_maintain_auto``
``slide_..._predictive``       ``sharded_slide_and_maintain_predictive``
``full_refresh``               ``sharded_full_refresh``
=============================  ========================================

The engines are semantics-agnostic by design: edge suspiciousness arrives
pre-weighted through one compiled :class:`repro.core.semantics.
SuspSemantics` (the service plane jits ``batch_weights`` once per
semantics), so a user-defined semantics reaches the sharded fast path
without touching this file.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.incremental import (
    BucketPredictor,
    DeviceSpadeState,
    WorksetTickInfo,
    _predictive_dispatch_core,
    _slide_epilogue,
    _slide_prologue,
)
from repro.core.peel import (
    PeelResultDevice,
    _compact_workset,
    _run_rounds,
    select_bucket,
)
from repro.graphstore.structs import DeviceGraph, compact_slots, remove_edges

__all__ = [
    "shard_graph",
    "sharded_peel_weights",
    "sharded_bulk_peel",
    "sharded_bulk_peel_warm",
    "sharded_bulk_peel_warm_workset",
    "init_sharded_state",
    "sharded_insert_and_maintain",
    "sharded_insert_and_maintain_auto",
    "sharded_insert_and_maintain_predictive",
    "sharded_delete_and_maintain",
    "sharded_slide_and_maintain",
    "sharded_slide_and_maintain_auto",
    "sharded_slide_and_maintain_predictive",
    "sharded_full_refresh",
]

_INF = jnp.float32(jnp.inf)


def _check_divisible(g: DeviceGraph, mesh: Mesh, axis: str) -> int:
    n_shards = mesh.shape[axis]
    if g.e_capacity % n_shards:
        raise ValueError(
            f"e_capacity={g.e_capacity} not divisible by mesh axis "
            f"{axis!r} ({n_shards} shards); use shard_graph() to pad+place"
        )
    return n_shards


def shard_graph(g: DeviceGraph, mesh: Mesh, axis: str = "data") -> DeviceGraph:
    """Pad ``e_capacity`` to a multiple of the shard count and place the
    graph: edge buffers block-sharded along ``axis``, vertex buffers
    replicated.  Padding slots are the standard inert self-loops
    (``src = dst = n_capacity - 1``, ``c = 0``, mask False) appended at
    the tail, after the free region the edge counter grows into."""
    n_shards = mesh.shape[axis]
    e_pad = -(-g.e_capacity // n_shards) * n_shards
    src, dst = np.asarray(g.src), np.asarray(g.dst)
    c, em = np.asarray(g.c), np.asarray(g.edge_mask)
    if e_pad != g.e_capacity:
        extra = e_pad - g.e_capacity
        pad_idx = np.full(extra, g.n_capacity - 1, np.int32)
        src = np.concatenate([src, pad_idx])
        dst = np.concatenate([dst, pad_idx])
        c = np.concatenate([c, np.zeros(extra, np.float32)])
        em = np.concatenate([em, np.zeros(extra, bool)])
    esh = NamedSharding(mesh, P(axis))
    vsh = NamedSharding(mesh, P())
    # vertex arrays round-trip through host: device_put of a live device
    # array can alias its buffer into the replicated copy, which a later
    # donation of the source graph would silently delete
    return DeviceGraph(
        src=jax.device_put(jnp.asarray(src), esh),
        dst=jax.device_put(jnp.asarray(dst), esh),
        c=jax.device_put(jnp.asarray(c), esh),
        edge_mask=jax.device_put(jnp.asarray(em), esh),
        a=jax.device_put(jnp.asarray(np.asarray(g.a)), vsh),
        vertex_mask=jax.device_put(jnp.asarray(np.asarray(g.vertex_mask)), vsh),
        n_capacity=g.n_capacity,
        e_capacity=e_pad,
    )


# ---------------------------------------------------------------------------
# sharded peel rounds (runs inside shard_map; one fused psum per round)
# ---------------------------------------------------------------------------


class _ShardState(NamedTuple):
    w: jax.Array  # [V] replicated
    active: jax.Array  # [V] replicated
    edge_alive: jax.Array  # [E/n_shards] LOCAL
    f: jax.Array
    n_act: jax.Array
    level: jax.Array
    best_g: jax.Array
    best_level: jax.Array
    round_: jax.Array


def _shard_round(axis, eps, src, dst, c, a, s: _ShardState) -> _ShardState:
    """One psum-reduced bulk round over per-shard COO arrays — the dist
    twin of :func:`repro.core.peel._round_step`, shared by the full-buffer
    and workset shard peels so the two cannot drift.  Vertex-shaped state
    (``s.w`` etc.) is replicated; edge arrays are a shard's local block
    (full buffers or a gathered workset alike)."""
    V = s.w.shape[0]
    g_cur = s.f / jnp.maximum(s.n_act, 1).astype(jnp.float32)
    improved = (g_cur > s.best_g) & (s.n_act > 0)
    best_g = jnp.where(improved, g_cur, s.best_g)
    best_level = jnp.where(improved, s.round_, s.best_level)
    thresh = 2.0 * (1.0 + eps) * g_cur
    peel = s.active & (s.w <= thresh)
    # f32-drift progress fallback, mirroring core.peel._round_step
    # (w is replicated, so every shard picks the same vertices)
    wmin = jnp.min(jnp.where(s.active, s.w, _INF))
    peel = jnp.where(jnp.any(peel), peel, s.active & (s.w <= wmin))
    e_ps = peel[src]
    e_pd = peel[dst]
    cm = jnp.where(s.edge_alive, c, 0.0)
    dw_l = jax.ops.segment_sum(
        jnp.where(e_ps & ~e_pd, cm, 0.0), dst, num_segments=V
    ) + jax.ops.segment_sum(
        jnp.where(e_pd & ~e_ps, cm, 0.0), src, num_segments=V
    )
    drop_l = jnp.sum(jnp.where(e_ps | e_pd, cm, 0.0))
    dw, drop = jax.lax.psum((dw_l, drop_l), axis)
    return _ShardState(
        w=s.w - dw,
        active=s.active & ~peel,
        edge_alive=s.edge_alive & ~(e_ps | e_pd),
        f=s.f - jnp.sum(jnp.where(peel, a, 0.0)) - drop,
        n_act=s.n_act - jnp.sum(peel),
        level=jnp.where(peel, s.round_, s.level),
        best_g=best_g,
        best_level=best_level,
        round_=s.round_ + 1,
    )


def _local_peel_fn(axis: str, V: int, eps: float, max_rounds: int, warm: bool):
    """Build the per-shard peel body.  ``warm`` restricts to the ``keep``
    suffix exactly like :func:`repro.core.peel.bulk_peel_warm`; cold start
    mirrors ``bulk_peel`` (same init, best tracker seeded by prior_g)."""

    def fn(src, dst, c, emask, a, vmask, keep, prior_g):
        if warm:
            live = keep & vmask
            alive0 = live[src] & live[dst] & emask
            w_base = jnp.where(live, a, 0.0)
        else:
            live = vmask
            alive0 = emask
            w_base = jnp.where(vmask, a, 0.0)
        cm0 = jnp.where(alive0, c, 0.0)
        inc = jax.ops.segment_sum(cm0, src, num_segments=V) + jax.ops.segment_sum(
            cm0, dst, num_segments=V
        )
        inc, e_sum = jax.lax.psum((inc, jnp.sum(cm0)), axis)
        init = _ShardState(
            w=w_base + inc,
            active=live,
            edge_alive=alive0,
            f=jnp.sum(w_base) + e_sum,
            n_act=jnp.sum(live),
            level=jnp.full(V, -1, jnp.int32),
            best_g=prior_g.astype(jnp.float32),
            best_level=jnp.int32(0),
            round_=jnp.int32(0),
        )
        s = _run_rounds(
            partial(_shard_round, axis, eps, src, dst, c, a), init, max_rounds
        )
        return s.level, s.best_level, s.best_g, s.round_, s.w

    return fn


def _sharded_peel(
    g: DeviceGraph,
    keep: jax.Array,
    prior_g: jax.Array,
    mesh: Mesh,
    axis: str,
    eps: float,
    max_rounds: int,
    warm: bool,
) -> PeelResultDevice:
    _check_divisible(g, mesh, axis)
    es, rs = P(axis), P()
    fn = _local_peel_fn(axis, g.n_capacity, eps, max_rounds, warm)
    level, best_level, best_g, n_rounds, w = shard_map(
        fn,
        mesh=mesh,
        in_specs=(es, es, es, es, rs, rs, rs, rs),
        out_specs=(rs,) * 5,
        check_rep=False,
    )(g.src, g.dst, g.c, g.edge_mask, g.a, g.vertex_mask, keep, prior_g)
    return PeelResultDevice(
        level=level,
        best_level=best_level,
        best_g=best_g,
        n_rounds=n_rounds,
        order=jnp.zeros(g.n_capacity, jnp.int32),
        delta=w,
    )


@partial(jax.jit, static_argnames=("mesh", "axis", "eps", "max_rounds"))
def sharded_bulk_peel(
    g: DeviceGraph,
    mesh: Mesh,
    axis: str = "data",
    eps: float = 0.1,
    max_rounds: int = 0,
) -> PeelResultDevice:
    """Edge-sharded twin of :func:`repro.core.peel.bulk_peel`."""
    return _sharded_peel(
        g, g.vertex_mask, -_INF, mesh, axis, eps, max_rounds, warm=False
    )


@partial(jax.jit, static_argnames=("mesh", "axis", "eps", "max_rounds"))
def sharded_bulk_peel_warm(
    g: DeviceGraph,
    keep: jax.Array,
    prior_best_g: jax.Array,
    mesh: Mesh,
    axis: str = "data",
    eps: float = 0.1,
    max_rounds: int = 0,
) -> PeelResultDevice:
    """Edge-sharded twin of :func:`repro.core.peel.bulk_peel_warm`."""
    return _sharded_peel(
        g, keep, prior_best_g, mesh, axis, eps, max_rounds, warm=True
    )


# ---------------------------------------------------------------------------
# sharded workset peel (DESIGN.md §8): each shard gathers the affected
# suffix's LOCAL live edges into a bucket-sized buffer; vertex compaction
# is replicated math, so every shard agrees on the local id map and the
# round sequence, and one psum per round reduces the workset deltas.
# ---------------------------------------------------------------------------


def _local_workset_peel_fn(
    axis: str, V: int, eps: float, max_rounds: int, v_bucket: int, e_bucket: int
):
    def fn(src, dst, c, emask, a, vmask, keep, prior_g):
        # the gather is core.peel._compact_workset verbatim on this shard's
        # local edge block; vertex compaction is replicated math, so every
        # shard computes the identical vid/local-id map and the round
        # sequence cannot diverge
        live = keep & vmask
        ws = _compact_workset(src, dst, c, emask, a, live, v_bucket, e_bucket)

        cm0 = jnp.where(ws.alive, ws.c, 0.0)
        inc = jax.ops.segment_sum(cm0, ws.src, num_segments=v_bucket) + (
            jax.ops.segment_sum(cm0, ws.dst, num_segments=v_bucket)
        )
        inc, e_sum = jax.lax.psum((inc, jnp.sum(cm0)), axis)
        init = _ShardState(
            w=ws.a + inc,
            active=ws.active,
            edge_alive=ws.alive,
            f=jnp.sum(ws.a) + e_sum,
            n_act=jnp.sum(ws.active),
            level=jnp.full(v_bucket, -1, jnp.int32),
            best_g=prior_g.astype(jnp.float32),
            best_level=jnp.int32(0),
            round_=jnp.int32(0),
        )
        s = _run_rounds(
            partial(_shard_round, axis, eps, ws.src, ws.dst, ws.c, ws.a),
            init, max_rounds,
        )
        # scatter the workset level back to full width (replicated output)
        level = jnp.full(V, -1, jnp.int32).at[ws.vid].set(s.level, mode="drop")
        w_full = jnp.zeros(V, jnp.float32).at[ws.vid].set(s.w, mode="drop")
        return level, s.best_level, s.best_g, s.round_, w_full

    return fn


@partial(
    jax.jit,
    static_argnames=("mesh", "axis", "eps", "max_rounds", "v_bucket", "e_bucket"),
)
def sharded_bulk_peel_warm_workset(
    g: DeviceGraph,
    keep: jax.Array,
    prior_best_g: jax.Array,
    mesh: Mesh,
    axis: str = "data",
    eps: float = 0.1,
    max_rounds: int = 0,
    *,
    v_bucket: int,
    e_bucket: int,
) -> PeelResultDevice:
    """Edge-sharded twin of :func:`repro.core.peel.bulk_peel_warm_workset`.

    ``e_bucket`` bounds the *per-shard* workset (callers size it from the
    max local suffix-edge count, :func:`sharded_workset_sizes`).  Matches
    the single-device workset and the full-buffer warm peel bit-exactly on
    integer weights: all per-vertex/per-set quantities are the same
    integer sums, only the reduction is distributed.
    """
    _check_divisible(g, mesh, axis)
    es, rs = P(axis), P()
    fn = _local_workset_peel_fn(axis, g.n_capacity, eps, max_rounds,
                                v_bucket, e_bucket)
    level, best_level, best_g, n_rounds, w = shard_map(
        fn,
        mesh=mesh,
        in_specs=(es, es, es, es, rs, rs, rs, rs),
        out_specs=(rs,) * 5,
        check_rep=False,
    )(g.src, g.dst, g.c, g.edge_mask, g.a, g.vertex_mask, keep, prior_best_g)
    return PeelResultDevice(
        level=level,
        best_level=best_level,
        best_g=best_g,
        n_rounds=n_rounds,
        order=jnp.zeros(g.n_capacity, jnp.int32),
        delta=w,
    )


@partial(jax.jit, static_argnames=("mesh", "axis"))
def sharded_workset_sizes(
    g: DeviceGraph, keep: jax.Array, mesh: Mesh, axis: str = "data"
) -> tuple[jax.Array, jax.Array]:
    """(live suffix vertices, MAX per-shard suffix-induced live edges) —
    the bucket-selection counts for the sharded workset path."""
    _check_divisible(g, mesh, axis)

    def fn(src, dst, c, emask, vmask, keep):
        live = keep & vmask
        both = live[src] & live[dst] & emask
        ne = jax.lax.pmax(jnp.sum(both).astype(jnp.int32), axis)
        return jnp.sum(live).astype(jnp.int32), ne

    es, rs = P(axis), P()
    return shard_map(
        fn, mesh=mesh, in_specs=(es, es, es, es, rs, rs), out_specs=(rs, rs),
        check_rep=False,
    )(g.src, g.dst, g.c, g.edge_mask, g.vertex_mask, keep)


@partial(jax.jit, static_argnames=("mesh", "axis"))
def sharded_peel_weights(g: DeviceGraph, mesh: Mesh, axis: str = "data") -> jax.Array:
    """Edge-sharded ``DeviceGraph.peel_weights`` (one psum)."""
    _check_divisible(g, mesh, axis)
    V = g.n_capacity

    def fn(src, dst, c, emask, a, vmask):
        cm = jnp.where(emask, c, 0.0)
        inc = jax.ops.segment_sum(cm, src, num_segments=V) + jax.ops.segment_sum(
            cm, dst, num_segments=V
        )
        return jnp.where(vmask, a, 0.0) + jax.lax.psum(inc, axis)

    es, rs = P(axis), P()
    return shard_map(
        fn, mesh=mesh, in_specs=(es, es, es, es, rs, rs), out_specs=rs,
        check_rep=False,
    )(g.src, g.dst, g.c, g.edge_mask, g.a, g.vertex_mask)


# ---------------------------------------------------------------------------
# sharded streaming maintenance
# ---------------------------------------------------------------------------


def _sharded_append(
    g: DeviceGraph,
    offset: jax.Array,
    src: jax.Array,
    dst: jax.Array,
    c: jax.Array,
    valid: jax.Array,
    mesh: Mesh,
    axis: str,
) -> DeviceGraph:
    """Sharded scatter-append: the batch is replicated; each shard writes
    the entries whose global compacted slot falls in its block."""
    n_shards = mesh.shape[axis]
    e_local = g.e_capacity // n_shards

    def append_local(ls, ld, lc, lm, bs, bd, bc, valid_b, off):
        lo = jax.lax.axis_index(axis).astype(jnp.int32) * e_local
        idx, ok = compact_slots(off, valid_b, g.e_capacity)
        li = idx - lo
        li = jnp.where(ok & (li >= 0) & (li < e_local), li, e_local)
        return (
            ls.at[li].set(bs.astype(jnp.int32), mode="drop"),
            ld.at[li].set(bd.astype(jnp.int32), mode="drop"),
            lc.at[li].set(bc.astype(jnp.float32), mode="drop"),
            lm.at[li].set(True, mode="drop"),
        )

    es, rs = P(axis), P()
    nsrc, ndst, nc, nmask = shard_map(
        append_local,
        mesh=mesh,
        in_specs=(es, es, es, es, rs, rs, rs, rs, rs),
        out_specs=(es,) * 4,
        check_rep=False,
    )(g.src, g.dst, g.c, g.edge_mask, src, dst, c, valid, offset)
    return dataclasses.replace(g, src=nsrc, dst=ndst, c=nc, edge_mask=nmask)


def _sharded_remove(
    g: DeviceGraph, drop: jax.Array, mesh: Mesh, axis: str
) -> tuple[DeviceGraph, jax.Array]:
    """``remove_edges`` over sharded buffers: the compaction scatter runs
    as plain jnp ops (GSPMD inserts the collectives) and the compacted
    buffers are constrained back onto ``axis``."""
    g, n_removed = remove_edges(g, drop)
    esh = NamedSharding(mesh, P(axis))
    return (
        dataclasses.replace(
            g,
            src=jax.lax.with_sharding_constraint(g.src, esh),
            dst=jax.lax.with_sharding_constraint(g.dst, esh),
            c=jax.lax.with_sharding_constraint(g.c, esh),
            edge_mask=jax.lax.with_sharding_constraint(g.edge_mask, esh),
        ),
        n_removed,
    )


def init_sharded_state(
    g: DeviceGraph, mesh: Mesh, axis: str = "data", eps: float = 0.1
) -> DeviceSpadeState:
    """Sharded twin of :func:`repro.core.incremental.init_state`; ``g``
    should come from :func:`shard_graph`."""
    res = sharded_bulk_peel(g, mesh, axis=axis, eps=eps)
    return DeviceSpadeState(
        graph=g,
        level=res.level,
        best_g=res.best_g,
        community=res.community_mask() & g.vertex_mask,
        edge_count=jnp.sum(g.edge_mask).astype(jnp.int32),
        w0=sharded_peel_weights(g, mesh, axis=axis),
    )


@partial(
    jax.jit,
    static_argnames=("mesh", "axis", "eps", "max_rounds"),
    donate_argnames=("state",),
)
def sharded_insert_and_maintain(
    state: DeviceSpadeState,
    src: jax.Array,
    dst: jax.Array,
    c: jax.Array,
    valid: jax.Array,
    mesh: Mesh,
    axis: str = "data",
    eps: float = 0.1,
    max_rounds: int = 0,
) -> DeviceSpadeState:
    """Edge-sharded twin of :func:`repro.core.incremental.insert_and_maintain`.

    One fused device program: sharded append (each shard writes the batch
    entries whose global slot falls in its block) -> affected-suffix
    recovery (replicated) -> sharded warm bulk re-peel -> state merge.
    The bookkeeping is the single-device ``_slide_prologue`` /
    ``_slide_epilogue`` with the insert-only static path (no drop mask),
    exactly as in the core engine, so the two planes cannot drift.
    """
    _check_divisible(state.graph, mesh, axis)
    bk = _slide_prologue(state, None, src, dst, valid)
    g = _sharded_append(state.graph, state.edge_count, src, dst, c, valid,
                        mesh, axis)
    res = _sharded_peel(
        g, bk.keep, bk.prior_g, mesh, axis, eps, max_rounds, warm=True
    )
    return _slide_epilogue(state, g, res, bk, jnp.int32(0), src, dst, c, valid,
                           with_drops=False)


def sharded_delete_and_maintain(
    state: DeviceSpadeState,
    drop: jax.Array,
    mesh: Mesh,
    axis: str = "data",
    eps: float = 0.1,
    max_rounds: int = 0,
) -> DeviceSpadeState:
    """Edge-sharded twin of :func:`repro.core.incremental.delete_and_maintain`
    — exactly a sharded window slide with an empty insert batch."""
    z = jnp.zeros(1, jnp.int32)
    return sharded_slide_and_maintain(
        state, drop, z, z, z.astype(jnp.float32), jnp.zeros(1, bool),
        mesh=mesh, axis=axis, eps=eps, max_rounds=max_rounds,
    )


@partial(
    jax.jit,
    static_argnames=("mesh", "axis", "eps", "max_rounds"),
    donate_argnames=("state",),
)
def sharded_slide_and_maintain(
    state: DeviceSpadeState,
    drop: jax.Array,
    src: jax.Array,
    dst: jax.Array,
    c: jax.Array,
    valid: jax.Array,
    mesh: Mesh,
    axis: str = "data",
    eps: float = 0.1,
    max_rounds: int = 0,
) -> DeviceSpadeState:
    """Edge-sharded twin of :func:`repro.core.incremental.slide_and_maintain`:
    one fused window tick — sharded compaction, sharded append, a single
    psum-reduced warm re-peel.  The suffix/density bookkeeping is the
    single-device ``_slide_prologue`` / ``_slide_epilogue`` verbatim
    (replicated math; GSPMD inserts the collectives), so the two engines
    cannot drift and the result matches the single-device path exactly on
    integer-valued suspiciousness."""
    _check_divisible(state.graph, mesh, axis)
    bk = _slide_prologue(state, drop, src, dst, valid)
    g, n_removed = _sharded_remove(state.graph, drop, mesh, axis)
    g = _sharded_append(
        g, state.edge_count - n_removed, src, dst, c, valid, mesh, axis
    )
    res = _sharded_peel(
        g, bk.keep, bk.prior_g, mesh, axis, eps, max_rounds, warm=True
    )
    return _slide_epilogue(state, g, res, bk, n_removed, src, dst, c, valid)


# ---------------------------------------------------------------------------
# sharded workset dispatch (DESIGN.md §8): phase A applies the structural
# update and counts the affected suffix; the host syncs the two scalars,
# picks buckets, and dispatches phase B (per-shard workset re-peel, or the
# full-buffer sharded warm peel on fallback).
# ---------------------------------------------------------------------------


@partial(jax.jit, static_argnames=("mesh", "axis"))
def _sharded_insert_phase_a(state, src, dst, c, valid, mesh, axis):
    bk = _slide_prologue(state, None, src, dst, valid)
    g = _sharded_append(state.graph, state.edge_count, src, dst, c, valid,
                        mesh, axis)
    nv, ne = sharded_workset_sizes(g, bk.keep, mesh, axis=axis)
    return g, bk, jnp.int32(0), nv, ne


@partial(jax.jit, static_argnames=("mesh", "axis"))
def _sharded_slide_phase_a(state, drop, src, dst, c, valid, mesh, axis):
    bk = _slide_prologue(state, drop, src, dst, valid)
    g, n_removed = _sharded_remove(state.graph, drop, mesh, axis)
    g = _sharded_append(g, state.edge_count - n_removed, src, dst, c, valid,
                        mesh, axis)
    nv, ne = sharded_workset_sizes(g, bk.keep, mesh, axis=axis)
    return g, bk, n_removed, nv, ne


@partial(
    jax.jit,
    static_argnames=("mesh", "axis", "eps", "max_rounds", "v_bucket",
                     "e_bucket", "with_drops", "d_bucket"),
    donate_argnames=("state", "g"),
)
def _sharded_phase_b(
    state, g, bk, n_removed, src, dst, c, valid,
    mesh, axis,
    eps: float = 0.1,
    max_rounds: int = 0,
    v_bucket: int = 0,
    e_bucket: int = 0,
    with_drops: bool = True,
    d_bucket: int = 0,
):
    if v_bucket and e_bucket:
        res = sharded_bulk_peel_warm_workset(
            g, bk.keep, bk.prior_g, mesh, axis=axis, eps=eps,
            max_rounds=max_rounds, v_bucket=v_bucket, e_bucket=e_bucket,
        )
    else:
        res = _sharded_peel(
            g, bk.keep, bk.prior_g, mesh, axis, eps, max_rounds, warm=True
        )
    return _slide_epilogue(state, g, res, bk, n_removed, src, dst, c, valid,
                           with_drops=with_drops, d_bucket=d_bucket)


def _sharded_dispatch_phase_b(
    state, g, bk, n_removed, src, dst, c, valid,
    nv, ne, mesh, axis, eps, max_rounds, min_bucket, with_drops=True,
) -> tuple[DeviceSpadeState, WorksetTickInfo]:
    n_cap = state.graph.n_capacity
    e_local = state.graph.e_capacity // mesh.shape[axis]
    # the tick's only device->host sync: three scalars, one transfer
    nv_i, ne_i, nd_i = (int(x) for x in np.asarray(
        jnp.stack([nv, ne, n_removed])
    ))
    bv = select_bucket(nv_i, n_cap, floor=min_bucket)
    be = select_bucket(ne_i, e_local, floor=min_bucket)
    if bv is None or be is None:
        bv = be = 0
    # statically skip the w0 decrement when nothing was actually dropped,
    # and compact it through a bucket otherwise (single-device engine ditto)
    with_drops = with_drops and nd_i > 0
    bd = 0
    if with_drops:
        bd = select_bucket(nd_i, state.graph.e_capacity,
                           floor=min_bucket) or 0
    new_state = _sharded_phase_b(
        state, g, bk, n_removed, src, dst, c, valid, mesh, axis,
        eps=eps, max_rounds=max_rounds, v_bucket=bv, e_bucket=be,
        with_drops=with_drops, d_bucket=bd,
    )
    return new_state, WorksetTickInfo(nv_i, ne_i, bv, be, not (bv and be))


def sharded_insert_and_maintain_auto(
    state: DeviceSpadeState,
    src: jax.Array,
    dst: jax.Array,
    c: jax.Array,
    valid: jax.Array,
    mesh: Mesh,
    axis: str = "data",
    eps: float = 0.1,
    max_rounds: int = 0,
    min_bucket: int = 64,
) -> tuple[DeviceSpadeState, WorksetTickInfo]:
    """Edge-sharded twin of
    :func:`repro.core.incremental.insert_and_maintain_auto`."""
    g, bk, n_removed, nv, ne = _sharded_insert_phase_a(
        state, src, dst, c, valid, mesh, axis
    )
    return _sharded_dispatch_phase_b(
        state, g, bk, n_removed, src, dst, c, valid, nv, ne, mesh, axis,
        eps, max_rounds, min_bucket, with_drops=False,
    )


def sharded_slide_and_maintain_auto(
    state: DeviceSpadeState,
    drop: jax.Array,
    src: jax.Array,
    dst: jax.Array,
    c: jax.Array,
    valid: jax.Array,
    mesh: Mesh,
    axis: str = "data",
    eps: float = 0.1,
    max_rounds: int = 0,
    min_bucket: int = 64,
) -> tuple[DeviceSpadeState, WorksetTickInfo]:
    """Edge-sharded twin of
    :func:`repro.core.incremental.slide_and_maintain_auto`."""
    g, bk, n_removed, nv, ne = _sharded_slide_phase_a(
        state, drop, src, dst, c, valid, mesh, axis
    )
    return _sharded_dispatch_phase_b(
        state, g, bk, n_removed, src, dst, c, valid, nv, ne, mesh, axis,
        eps, max_rounds, min_bucket,
    )


# ---------------------------------------------------------------------------
# sharded predictive dispatch: the core engine's BucketPredictor drives the
# mesh path too — buckets from the previous tick's (pmax'd per-shard)
# counts, fit-checked on device, counts drained after dispatch
# ---------------------------------------------------------------------------


@partial(
    jax.jit,
    static_argnames=("mesh", "axis", "eps", "max_rounds", "v_bucket",
                     "e_bucket", "with_drops", "d_bucket"),
    donate_argnames=("state", "g"),
)
def _sharded_phase_b_checked(
    state, g, bk, n_removed, nv, ne, src, dst, c, valid,
    mesh, axis,
    eps: float = 0.1,
    max_rounds: int = 0,
    v_bucket: int = 0,
    e_bucket: int = 0,
    with_drops: bool = True,
    d_bucket: int = 0,
):
    """Sharded twin of :func:`repro.core.incremental._phase_b_checked`:
    ``lax.cond`` between the per-shard workset peel and the full-buffer
    sharded warm peel, driven by the replicated count scalars."""
    fits = (nv <= jnp.int32(v_bucket)) & (ne <= jnp.int32(e_bucket))
    res = jax.lax.cond(
        fits,
        lambda: sharded_bulk_peel_warm_workset(
            g, bk.keep, bk.prior_g, mesh, axis=axis, eps=eps,
            max_rounds=max_rounds, v_bucket=v_bucket, e_bucket=e_bucket,
        ),
        lambda: _sharded_peel(
            g, bk.keep, bk.prior_g, mesh, axis, eps, max_rounds, warm=True
        ),
    )
    return _slide_epilogue(state, g, res, bk, n_removed, src, dst, c, valid,
                           with_drops=with_drops, d_bucket=d_bucket), fits


def _sharded_predictive_dispatch(
    state, g, bk, n_removed, src, dst, c, valid, nv, ne,
    predictor: BucketPredictor, mesh, axis, eps, max_rounds,
    with_drops=True, n_dropped=None,
) -> tuple[DeviceSpadeState, WorksetTickInfo]:
    """Sharded binding of the shared predictor-driven dispatcher
    (:func:`repro.core.incremental._predictive_dispatch_core`): only the
    three phase-B callables differ from the single-device engine."""
    return _predictive_dispatch_core(
        state, nv, ne, predictor, with_drops, n_dropped,
        synced=lambda wd: _sharded_dispatch_phase_b(
            state, g, bk, n_removed, src, dst, c, valid, nv, ne, mesh, axis,
            eps, max_rounds, predictor.min_bucket, with_drops=wd,
        ),
        checked=lambda bv, be, wd, bd: _sharded_phase_b_checked(
            state, g, bk, n_removed, nv, ne, src, dst, c, valid, mesh, axis,
            eps=eps, max_rounds=max_rounds, v_bucket=bv, e_bucket=be,
            with_drops=wd, d_bucket=bd,
        ),
        full=lambda wd, bd: _sharded_phase_b(
            state, g, bk, n_removed, src, dst, c, valid, mesh, axis,
            eps=eps, max_rounds=max_rounds, v_bucket=0, e_bucket=0,
            with_drops=wd, d_bucket=bd,
        ),
    )


def sharded_insert_and_maintain_predictive(
    state: DeviceSpadeState,
    src: jax.Array,
    dst: jax.Array,
    c: jax.Array,
    valid: jax.Array,
    predictor: BucketPredictor,
    mesh: Mesh,
    axis: str = "data",
    eps: float = 0.1,
    max_rounds: int = 0,
) -> tuple[DeviceSpadeState, WorksetTickInfo]:
    """Edge-sharded twin of
    :func:`repro.core.incremental.insert_and_maintain_predictive`.
    ``predictor.e_capacity`` must be the per-shard local capacity."""
    g, bk, n_removed, nv, ne = _sharded_insert_phase_a(
        state, src, dst, c, valid, mesh, axis
    )
    return _sharded_predictive_dispatch(
        state, g, bk, n_removed, src, dst, c, valid, nv, ne, predictor,
        mesh, axis, eps, max_rounds, with_drops=False, n_dropped=0,
    )


def sharded_slide_and_maintain_predictive(
    state: DeviceSpadeState,
    drop: jax.Array,
    src: jax.Array,
    dst: jax.Array,
    c: jax.Array,
    valid: jax.Array,
    predictor: BucketPredictor,
    mesh: Mesh,
    axis: str = "data",
    n_dropped: int | None = None,
    eps: float = 0.1,
    max_rounds: int = 0,
) -> tuple[DeviceSpadeState, WorksetTickInfo]:
    """Edge-sharded twin of
    :func:`repro.core.incremental.slide_and_maintain_predictive`."""
    g, bk, n_removed, nv, ne = _sharded_slide_phase_a(
        state, drop, src, dst, c, valid, mesh, axis
    )
    return _sharded_predictive_dispatch(
        state, g, bk, n_removed, src, dst, c, valid, nv, ne, predictor,
        mesh, axis, eps, max_rounds, n_dropped=n_dropped,
    )


@partial(jax.jit, static_argnames=("mesh", "axis", "eps"))
def sharded_full_refresh(
    state: DeviceSpadeState, mesh: Mesh, axis: str = "data", eps: float = 0.1
) -> DeviceSpadeState:
    """Edge-sharded twin of :func:`repro.core.incremental.full_refresh`."""
    res = sharded_bulk_peel(state.graph, mesh, axis=axis, eps=eps)
    return DeviceSpadeState(
        graph=state.graph,
        level=res.level,
        best_g=res.best_g,
        community=res.community_mask() & state.graph.vertex_mask,
        edge_count=state.edge_count,
        w0=sharded_peel_weights(state.graph, mesh, axis=axis),
    )
