"""Device-plane streaming fraud service: the multi-pod serving loop.

The host service (:mod:`repro.serve.service`) is the paper's single-box
deployment; this loop is the pod-scale twin: fixed-size batched ticks
through the TPU-native engine (``insert_and_maintain``), FD/DW/DG
weighting on device, benign/urgent statistics, periodic exact refresh, and
capacity management.  On a real cluster each tick is one device program
under the production mesh; here it runs on the CPU backend.
"""

from __future__ import annotations

import dataclasses
import time
from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.device_metrics import dg_weights, dw_weights, fd_batch_weights
from repro.core.incremental import (
    DeviceSpadeState,
    benign_mask,
    full_refresh,
    init_state,
    insert_and_maintain,
    slide_and_maintain,
)
from repro.dist.graph import (
    init_sharded_state,
    shard_graph,
    sharded_full_refresh,
    sharded_insert_and_maintain,
    sharded_slide_and_maintain,
)
from repro.graphstore.generators import TxStream
from repro.graphstore.structs import device_graph_from_coo

__all__ = ["DeviceServiceReport", "run_device_service"]


@dataclass
class DeviceServiceReport:
    n_edges: int
    n_ticks: int
    mean_tick_seconds: float
    mean_us_per_edge: float
    benign_fraction: float
    fraud_recall: float
    final_g: float
    n_refreshes: int
    window_ticks: int = 0  # 0 = unbounded (insert-only) service
    n_expired_edges: int = 0  # edges that slid out of the window
    live_edges: int = 0  # edges resident at shutdown


def run_device_service(
    stream: TxStream,
    metric: str = "DW",
    batch_edges: int = 1024,
    eps: float = 0.1,
    max_rounds: int = 20,
    refresh_every: int = 0,
    capacity_slack: float = 1.3,
    mesh: jax.sharding.Mesh | None = None,
    shard_axis: str = "data",
    window_ticks: int = 0,
) -> DeviceServiceReport:
    """Replay ``stream`` through the device engine in fixed-size ticks.

    With ``mesh=`` the edge buffers are block-sharded along ``shard_axis``
    (vertex state replicated) and every tick runs the dist plane's
    psum-reduced engine (:mod:`repro.dist.graph`); without it, the
    single-device engine.  Results are identical up to reduction-order
    rounding.

    With ``window_ticks=N > 0`` the service runs in **sliding-window mode**
    (paper Appendix C.3): each tick expires the stream batch falling out
    of an N-tick ring *and* inserts the new batch in one fused
    ``slide_and_maintain`` device program (a single warm re-peel covers
    both updates), so only the base graph plus the last N ticks of
    transactions are resident.  Because ``remove_edges`` compacts
    survivors to the buffer prefix, the oldest resident batch always
    occupies the slots right after the base graph and the edge capacity
    is bounded by ``m_base + (N+1) * batch_edges`` regardless of stream
    length."""
    n = stream.n_vertices
    m_base = stream.base_src.shape[0]
    m_total = m_base + stream.inc_src.shape[0]
    if window_ticks:
        e_cap = m_base + (window_ticks + 1) * batch_edges
    else:
        e_cap = int(m_total * capacity_slack) + batch_edges

    if metric == "DG":
        base_w = np.ones(m_base, np.float32)
    else:
        base_w = stream.base_amt.astype(np.float32)
    in_deg = np.zeros(n, np.int64)
    np.add.at(in_deg, stream.base_dst, 1)
    if metric == "FD":
        base_w = (1.0 / np.log(in_deg[stream.base_dst] + 5.0)).astype(np.float32)

    g = device_graph_from_coo(
        n, stream.base_src, stream.base_dst, base_w,
        n_capacity=-(-n // 512) * 512, e_capacity=-(-e_cap // 512) * 512,
    )
    if mesh is not None:
        g = shard_graph(g, mesh, axis=shard_axis)
        state = init_sharded_state(g, mesh, axis=shard_axis, eps=eps)
        maintain = partial(sharded_insert_and_maintain, mesh=mesh, axis=shard_axis)
        refresh = partial(sharded_full_refresh, mesh=mesh, axis=shard_axis)
        slide = partial(sharded_slide_and_maintain, mesh=mesh, axis=shard_axis)
    else:
        state = init_state(g, eps=eps)
        maintain = insert_and_maintain
        refresh = full_refresh
        slide = slide_and_maintain
    deg_dev = jnp.zeros(g.n_capacity, jnp.int32).at[
        jnp.asarray(stream.base_dst)
    ].add(1)

    n_inc = stream.inc_src.shape[0]
    n_ticks = 0
    n_refresh = 0
    benign_total = 0
    n_expired = 0
    t_total = 0.0
    ring: list[int] = []  # per-tick resident edge counts, oldest first
    detected: set[int] = set()  # windowed mode: vertices ever in S^P
    slot_ids = jnp.arange(g.e_capacity, dtype=jnp.int32)
    for i in range(0, n_inc, batch_edges):
        j = min(i + batch_edges, n_inc)
        pad = batch_edges - (j - i)
        bs = np.concatenate([stream.inc_src[i:j], np.zeros(pad, np.int64)])
        bd = np.concatenate([stream.inc_dst[i:j], np.zeros(pad, np.int64)])
        amt = np.concatenate([stream.inc_amt[i:j], np.zeros(pad)])
        valid = np.concatenate([np.ones(j - i, bool), np.zeros(pad, bool)])
        bs_d = jnp.asarray(bs, jnp.int32)
        bd_d = jnp.asarray(bd, jnp.int32)
        valid_d = jnp.asarray(valid)
        if metric == "FD":
            w, deg_dev = fd_batch_weights(deg_dev, bd_d, valid_d)
        elif metric == "DG":
            w = dg_weights(jnp.asarray(amt, jnp.float32))
        else:
            w = dw_weights(jnp.asarray(amt, jnp.float32))
        # padded tail lanes of a partial tick must not count toward stats
        benign_total += int(np.asarray(benign_mask(state, bs_d, bd_d, w))[valid].sum())
        t0 = time.perf_counter()
        if window_ticks and len(ring) >= window_ticks:
            # fused tick: expire the batch sliding out + insert the new one
            # in a single device program (one warm re-peel).  After
            # compaction the oldest resident batch always sits right after
            # the base graph.
            cnt0 = ring.pop(0)
            drop = (slot_ids >= m_base) & (slot_ids < m_base + cnt0)
            state = slide(
                state, drop, bs_d, bd_d, w.astype(jnp.float32), valid_d,
                eps=eps, max_rounds=max_rounds,
            )
            n_expired += cnt0
        else:
            state = maintain(
                state, bs_d, bd_d, w.astype(jnp.float32), valid_d,
                eps=eps, max_rounds=max_rounds,
            )
        jax.block_until_ready(state.best_g)
        t_total += time.perf_counter() - t0
        if window_ticks:
            ring.append(int(valid.sum()))
            # a windowed community is transient by design (the evidence
            # expires); recall is therefore "ever detected while resident"
            detected.update(np.where(np.asarray(state.community))[0].tolist())
        n_ticks += 1
        if refresh_every and n_ticks % refresh_every == 0:
            state = refresh(state, eps=eps)
            n_refresh += 1

    comm = set(np.where(np.asarray(state.community))[0].tolist()) | detected
    fraud = set(stream.fraud_block.tolist())
    recall = len(fraud & comm) / len(fraud) if fraud else 1.0
    return DeviceServiceReport(
        n_edges=n_inc,
        n_ticks=n_ticks,
        mean_tick_seconds=t_total / max(n_ticks, 1),
        mean_us_per_edge=1e6 * t_total / max(n_inc, 1),
        benign_fraction=benign_total / max(n_inc, 1),
        fraud_recall=recall,
        final_g=float(state.best_g),
        n_refreshes=n_refresh,
        window_ticks=window_ticks,
        n_expired_edges=n_expired,
        live_edges=int(state.edge_count),
    )
