"""DEPRECATED device-plane entrypoint (legacy ``metric: str`` flag soup).

The serving loop now lives in :mod:`repro.serve.spade_service` behind the
:class:`~repro.serve.spade_service.SpadeService` facade; this module keeps
the old 12-keyword ``run_device_service`` signature working as a shim that
translates its flags into an :class:`~repro.serve.spade_service.EngineSpec`
(``predictive=False``: the legacy workset mode is the synced-scalar
dispatcher, exactly as before).  Each call emits a
:class:`~repro._warnings.SpadeDeprecationWarning`.
"""

from __future__ import annotations

import warnings

import jax

from repro._warnings import SpadeDeprecationWarning
from repro.graphstore.generators import TxStream
from repro.serve.spade_service import DeviceServiceReport, EngineSpec, SpadeService

__all__ = ["DeviceServiceReport", "run_device_service"]


def run_device_service(
    stream: TxStream,
    metric: str = "DW",
    batch_edges: int = 1024,
    eps: float = 0.1,
    max_rounds: int = 20,
    refresh_every: int = 0,
    capacity_slack: float = 1.3,
    mesh: jax.sharding.Mesh | None = None,
    shard_axis: str = "data",
    window_ticks: int = 0,
    workset: bool = False,
    min_bucket: int = 64,
) -> DeviceServiceReport:
    """DEPRECATED shim: use ``SpadeService(semantics, EngineSpec(...))``.

    Flag-for-flag equivalent to the old loop (same seeding, same engines,
    synced-scalar workset dispatch); ``metric`` resolves through the one
    semantics registry, so registered custom semantics work here too.
    """
    warnings.warn(
        "run_device_service is deprecated; use repro.serve.SpadeService "
        "with an EngineSpec (semantics=... replaces metric=...)",
        SpadeDeprecationWarning,
        stacklevel=2,
    )
    spec = EngineSpec(
        plane="device",
        mesh=mesh,
        shard_axis=shard_axis,
        batch_edges=batch_edges,
        eps=eps,
        max_rounds=max_rounds,
        refresh_every=refresh_every,
        capacity_slack=capacity_slack,
        window_ticks=window_ticks,
        workset=workset,
        predictive=False,
        min_bucket=min_bucket,
    )
    return SpadeService(semantics=metric, spec=spec).run(stream)
