"""Device-plane streaming fraud service: the multi-pod serving loop.

The host service (:mod:`repro.serve.service`) is the paper's single-box
deployment; this loop is the pod-scale twin: fixed-size batched ticks
through the TPU-native engine (``insert_and_maintain``), FD/DW/DG
weighting on device, benign/urgent statistics, periodic exact refresh, and
capacity management.  On a real cluster each tick is one device program
under the production mesh; here it runs on the CPU backend.

With ``workset=True`` every tick runs through the affected-area workset
engine (DESIGN.md §8): phase A applies the structural update and counts
the affected suffix, the host picks power-of-two buckets from those two
scalars, and phase B re-peels only the gathered workset — falling back to
the full-buffer warm peel when the suffix exceeds the largest bucket.
Per-tick telemetry (workset vs fallback, bucket high-water marks) lands in
the report.

Per-tick statistics stay on device: benign counts accumulate in a device
scalar and the ever-detected vertex set in a device bool vector, drained
once at shutdown — no device->host round-trip inside the serving loop
beyond the workset engine's two count scalars.
"""

from __future__ import annotations

import time
from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.device_metrics import (
    dg_weights,
    dw_weights,
    fd_batch_weights,
    seed_base_weights,
)
from repro.core.incremental import (
    DeviceSpadeState,
    benign_mask,
    full_refresh,
    init_state,
    insert_and_maintain,
    insert_and_maintain_auto,
    slide_and_maintain,
    slide_and_maintain_auto,
)
from repro.dist.graph import (
    init_sharded_state,
    shard_graph,
    sharded_full_refresh,
    sharded_insert_and_maintain,
    sharded_insert_and_maintain_auto,
    sharded_slide_and_maintain,
    sharded_slide_and_maintain_auto,
)
from repro.graphstore.generators import TxStream
from repro.graphstore.structs import device_graph_from_coo

__all__ = ["DeviceServiceReport", "run_device_service"]


@dataclass
class DeviceServiceReport:
    n_edges: int
    n_ticks: int
    mean_tick_seconds: float
    mean_us_per_edge: float
    benign_fraction: float
    fraud_recall: float
    final_g: float
    n_refreshes: int
    window_ticks: int = 0  # 0 = unbounded (insert-only) service
    n_expired_edges: int = 0  # edges that slid out of the window
    live_edges: int = 0  # edges resident at shutdown
    # workset-engine telemetry (zeros when workset=False).  Edge counts
    # follow WorksetTickInfo semantics: global on a single device, max
    # PER-SHARD under a mesh — not comparable across the two modes.
    n_workset_ticks: int = 0
    n_fallback_ticks: int = 0
    max_suffix_edges: int = 0  # high-water mark of the affected suffix
    max_e_bucket: int = 0  # largest edge bucket dispatched


@jax.jit
def _accum_benign(acc, state: DeviceSpadeState, src, dst, c, valid):
    """Device-side benign counter (Def 4.1 against the PRE-tick state);
    padded tail lanes of a partial tick must not count toward stats."""
    return acc + jnp.sum(benign_mask(state, src, dst, c) & valid)


@jax.jit
def _accum_detected(ever, community):
    return ever | community


def run_device_service(
    stream: TxStream,
    metric: str = "DW",
    batch_edges: int = 1024,
    eps: float = 0.1,
    max_rounds: int = 20,
    refresh_every: int = 0,
    capacity_slack: float = 1.3,
    mesh: jax.sharding.Mesh | None = None,
    shard_axis: str = "data",
    window_ticks: int = 0,
    workset: bool = False,
    min_bucket: int = 64,
) -> DeviceServiceReport:
    """Replay ``stream`` through the device engine in fixed-size ticks.

    With ``mesh=`` the edge buffers are block-sharded along ``shard_axis``
    (vertex state replicated) and every tick runs the dist plane's
    psum-reduced engine (:mod:`repro.dist.graph`); without it, the
    single-device engine.  Results are identical up to reduction-order
    rounding.

    With ``window_ticks=N > 0`` the service runs in **sliding-window mode**
    (paper Appendix C.3): each tick expires the stream batch falling out
    of an N-tick ring *and* inserts the new batch in one fused
    ``slide_and_maintain`` device program (a single warm re-peel covers
    both updates), so only the base graph plus the last N ticks of
    transactions are resident.  Because ``remove_edges`` compacts
    survivors to the buffer prefix, the oldest resident batch always
    occupies the slots right after the base graph and the edge capacity
    is bounded by ``m_base + (N+1) * batch_edges`` regardless of stream
    length.

    With ``workset=True`` ticks dispatch through the workset engine
    (bit-identical on integer weights; automatic full-buffer fallback),
    turning steady-state per-round work from O(E_capacity) into
    O(|affected suffix|)."""
    n = stream.n_vertices
    m_base = stream.base_src.shape[0]
    m_total = m_base + stream.inc_src.shape[0]
    if window_ticks:
        e_cap = m_base + (window_ticks + 1) * batch_edges
    else:
        e_cap = int(m_total * capacity_slack) + batch_edges

    # one shared definition of the FD/DW/DG base seeding (dyadic-snapped)
    base_w, in_deg = seed_base_weights(
        metric, stream.base_src, stream.base_dst, stream.base_amt, n
    )

    g = device_graph_from_coo(
        n, stream.base_src, stream.base_dst, base_w,
        n_capacity=-(-n // 512) * 512, e_capacity=-(-e_cap // 512) * 512,
    )
    if mesh is not None:
        g = shard_graph(g, mesh, axis=shard_axis)
        state = init_sharded_state(g, mesh, axis=shard_axis, eps=eps)
        refresh = partial(sharded_full_refresh, mesh=mesh, axis=shard_axis)
        if workset:
            maintain = partial(sharded_insert_and_maintain_auto, mesh=mesh,
                               axis=shard_axis, min_bucket=min_bucket)
            slide = partial(sharded_slide_and_maintain_auto, mesh=mesh,
                            axis=shard_axis, min_bucket=min_bucket)
        else:
            maintain = partial(sharded_insert_and_maintain, mesh=mesh,
                               axis=shard_axis)
            slide = partial(sharded_slide_and_maintain, mesh=mesh,
                            axis=shard_axis)
    else:
        state = init_state(g, eps=eps)
        refresh = full_refresh
        if workset:
            maintain = partial(insert_and_maintain_auto, min_bucket=min_bucket)
            slide = partial(slide_and_maintain_auto, min_bucket=min_bucket)
        else:
            maintain = insert_and_maintain
            slide = slide_and_maintain
    deg_dev = jnp.asarray(in_deg, jnp.int32)
    if deg_dev.shape[0] < g.n_capacity:
        deg_dev = jnp.pad(deg_dev, (0, g.n_capacity - deg_dev.shape[0]))

    n_inc = stream.inc_src.shape[0]
    n_ticks = 0
    n_refresh = 0
    n_expired = 0
    t_total = 0.0
    n_workset = 0
    n_fallback = 0
    max_suffix_edges = 0
    max_e_bucket = 0
    ring: list[int] = []  # per-tick resident edge counts, oldest first
    benign_acc = jnp.int32(0)  # device accumulator, drained at shutdown
    ever_detected = jnp.zeros(g.n_capacity, bool)  # vertices ever in S^P
    slot_ids = jnp.arange(g.e_capacity, dtype=jnp.int32)
    for i in range(0, n_inc, batch_edges):
        j = min(i + batch_edges, n_inc)
        pad = batch_edges - (j - i)
        bs = np.concatenate([stream.inc_src[i:j], np.zeros(pad, np.int64)])
        bd = np.concatenate([stream.inc_dst[i:j], np.zeros(pad, np.int64)])
        amt = np.concatenate([stream.inc_amt[i:j], np.zeros(pad)])
        valid = np.concatenate([np.ones(j - i, bool), np.zeros(pad, bool)])
        bs_d = jnp.asarray(bs, jnp.int32)
        bd_d = jnp.asarray(bd, jnp.int32)
        valid_d = jnp.asarray(valid)
        if metric == "FD":
            w, deg_dev = fd_batch_weights(deg_dev, bd_d, valid_d)
        elif metric == "DG":
            w = dg_weights(jnp.asarray(amt, jnp.float32))
        else:
            w = dw_weights(jnp.asarray(amt, jnp.float32))
        benign_acc = _accum_benign(benign_acc, state, bs_d, bd_d, w, valid_d)
        t0 = time.perf_counter()
        info = None
        if window_ticks and len(ring) >= window_ticks:
            # fused tick: expire the batch sliding out + insert the new one
            # with a single warm re-peel.  After compaction the oldest
            # resident batch always sits right after the base graph.
            cnt0 = ring.pop(0)
            drop = (slot_ids >= m_base) & (slot_ids < m_base + cnt0)
            out = slide(
                state, drop, bs_d, bd_d, w.astype(jnp.float32), valid_d,
                eps=eps, max_rounds=max_rounds,
            )
            state, info = out if workset else (out, None)
            n_expired += cnt0
        else:
            out = maintain(
                state, bs_d, bd_d, w.astype(jnp.float32), valid_d,
                eps=eps, max_rounds=max_rounds,
            )
            state, info = out if workset else (out, None)
        jax.block_until_ready(state.best_g)
        t_total += time.perf_counter() - t0
        if info is not None:
            n_fallback += info.fallback
            n_workset += not info.fallback
            max_suffix_edges = max(max_suffix_edges, info.n_suffix_edges)
            max_e_bucket = max(max_e_bucket, info.e_bucket)
        if window_ticks:
            ring.append(int(valid.sum()))
            # a windowed community is transient by design (the evidence
            # expires); recall is therefore "ever detected while resident",
            # tracked as a device bool vector and drained once at shutdown
            ever_detected = _accum_detected(ever_detected, state.community)
        n_ticks += 1
        if refresh_every and n_ticks % refresh_every == 0:
            state = refresh(state, eps=eps)
            n_refresh += 1

    # drain the device-resident stats once, after the loop
    benign_total = int(benign_acc)
    detected = np.where(np.asarray(ever_detected))[0].tolist()
    comm = set(np.where(np.asarray(state.community))[0].tolist()) | set(detected)
    fraud = set(stream.fraud_block.tolist())
    recall = len(fraud & comm) / len(fraud) if fraud else 1.0
    return DeviceServiceReport(
        n_edges=n_inc,
        n_ticks=n_ticks,
        mean_tick_seconds=t_total / max(n_ticks, 1),
        mean_us_per_edge=1e6 * t_total / max(n_inc, 1),
        benign_fraction=benign_total / max(n_inc, 1),
        fraud_recall=recall,
        final_g=float(state.best_g),
        n_refreshes=n_refresh,
        window_ticks=window_ticks,
        n_expired_edges=n_expired,
        live_edges=int(state.edge_count),
        n_workset_ticks=n_workset,
        n_fallback_ticks=n_fallback,
        max_suffix_edges=max_suffix_edges,
        max_e_bucket=max_e_bucket,
    )
