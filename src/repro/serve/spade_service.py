"""``SpadeService``: one facade over every serving plane.

The serving surface used to be a flag soup: ``run_service`` (host oracle)
and ``run_device_service`` (12 keywords spanning single-device, mesh-
sharded, windowed, and workset modes), each with its own ``metric: str``
dispatch.  The facade collapses both into

    ``SpadeService(semantics, spec: EngineSpec).run(stream)``

where :class:`EngineSpec` is a declarative description of *where and how*
to serve (plane, mesh, window, workset, predictive buckets, grouping) and
``semantics`` is *what to measure* — a
:class:`repro.core.semantics.SuspSemantics` (or registered name) compiled
once and threaded through whichever engines the spec selects.  A
user-defined semantics therefore reaches every fast path with zero engine
edits; the legacy entrypoints remain as deprecation shims
(:mod:`repro.serve.service`, :mod:`repro.serve.device_service`).

The device serving loop here is the production tick pipeline:

* base graph seeded through the semantics' batch-seeding rule (dyadic
  snap at the protocol boundary, vertex priors included),
* per-tick weighting by the semantics' jit-compiled ``batch_weights``
  (arrival-time degrees for degree-using semantics, per-edge aux payload
  — the transaction timestamp — for aux-using ones),
* maintenance through the fused, workset, or predictive-workset engine,
  single-device or mesh-sharded,
* per-tick statistics accumulated on device and drained at shutdown.

With ``workset=True, predictive=True`` (the default) the workset buckets
come from the previous tick's suffix counts and the fit check runs on
device (``bulk_peel_warm_checked``), so the serving loop issues **no
blocking device->host transfer at all**: the counts are drained after
phase B is already in flight.  A bucket miss rides the in-program
full-buffer fallback and re-anchors the predictor (DESIGN.md §8/§9).
"""

from __future__ import annotations

import dataclasses
import time
from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.incremental import (
    BucketPredictor,
    DeviceSpadeState,
    benign_mask,
    full_refresh,
    init_state,
    insert_and_maintain,
    insert_and_maintain_auto,
    insert_and_maintain_predictive,
    slide_and_maintain,
    slide_and_maintain_auto,
    slide_and_maintain_predictive,
)
from repro.core.metrics import DensityMetric
from repro.core.semantics import SuspSemantics, resolve
from repro.dist.graph import (
    init_sharded_state,
    shard_graph,
    sharded_full_refresh,
    sharded_insert_and_maintain,
    sharded_insert_and_maintain_auto,
    sharded_insert_and_maintain_predictive,
    sharded_slide_and_maintain,
    sharded_slide_and_maintain_auto,
    sharded_slide_and_maintain_predictive,
)
from repro.graphstore.generators import TxStream
from repro.graphstore.structs import device_graph_from_coo

__all__ = ["EngineSpec", "SpadeService", "DeviceServiceReport"]


@dataclass(frozen=True)
class EngineSpec:
    """Declarative serving-engine configuration (the *where and how*).

    Device-plane fields: ``mesh``/``shard_axis`` (edge buffers block-
    sharded, vertex state replicated), ``window_ticks`` (N-tick sliding
    window; 0 = unbounded insert-only), ``workset`` (affected-area
    engine), ``predictive`` (previous-tick bucket prediction — drops the
    serving loop's only blocking device->host sync; ignored unless
    ``workset``), ``min_bucket``, ``batch_edges`` (tick size), ``eps``,
    ``max_rounds``, ``refresh_every``, ``capacity_slack``.

    Host-plane fields: ``grouping`` (benign/urgent edge grouping, Def
    4.1), ``flush_every`` (simulated seconds between forced buffer
    flushes), ``batch_edges`` (edges per InsertBatchEdges call).

    ``batch_edges = None`` resolves per plane — 1024-edge device ticks,
    per-edge (batch 1) host reorders, the paper's deployment shape for
    each — so migrating a legacy ``run_service`` call to the facade does
    not silently change the host batch size.
    """

    plane: str = "device"  # "device" | "host"
    mesh: jax.sharding.Mesh | None = None
    shard_axis: str = "data"
    batch_edges: int | None = None
    eps: float = 0.1
    max_rounds: int = 20
    refresh_every: int = 0
    capacity_slack: float = 1.3
    window_ticks: int = 0
    workset: bool = False
    predictive: bool = True
    min_bucket: int = 64
    grouping: bool = True
    flush_every: float = 1.0

    def __post_init__(self):
        if self.plane not in ("device", "host"):
            raise ValueError(f"plane must be 'device' or 'host', got {self.plane!r}")
        if self.batch_edges is not None and self.batch_edges <= 0:
            raise ValueError("batch_edges must be positive")
        if self.plane == "host" and (self.mesh is not None or self.workset
                                     or self.window_ticks):
            raise ValueError(
                "mesh/workset/window_ticks are device-plane settings; "
                "the host oracle serves per-edge with grouping/flush_every"
            )

    @property
    def effective_batch_edges(self) -> int:
        """``batch_edges`` with the per-plane default resolved."""
        if self.batch_edges is not None:
            return self.batch_edges
        return 1024 if self.plane == "device" else 1


@dataclass
class DeviceServiceReport:
    n_edges: int
    n_ticks: int
    mean_tick_seconds: float
    mean_us_per_edge: float
    benign_fraction: float
    fraud_recall: float
    final_g: float
    n_refreshes: int
    window_ticks: int = 0  # 0 = unbounded (insert-only) service
    n_expired_edges: int = 0  # edges that slid out of the window
    live_edges: int = 0  # edges resident at shutdown
    # workset-engine telemetry (zeros when workset=False).  Edge counts
    # follow WorksetTickInfo semantics: global on a single device, max
    # PER-SHARD under a mesh — not comparable across the two modes.
    n_workset_ticks: int = 0
    n_fallback_ticks: int = 0
    max_suffix_edges: int = 0  # high-water mark of the affected suffix
    max_e_bucket: int = 0  # largest edge bucket dispatched
    # predictive-selector telemetry (zeros when predictive=False)
    n_predicted_ticks: int = 0  # ticks dispatched without a count sync
    n_bucket_miss_ticks: int = 0  # predicted buckets the suffix outgrew


class SpadeService:
    """The one serving entrypoint: a compiled semantics x an engine spec.

    ``semantics`` is a registered name, a :class:`SuspSemantics`, or (host
    plane only) a legacy :class:`DensityMetric`.  ``spec`` defaults to the
    single-device streaming engine; keyword overrides are merged into it
    (``SpadeService("FD", window_ticks=8, workset=True)``).
    """

    def __init__(
        self,
        semantics: SuspSemantics | DensityMetric | str = "DW",
        spec: EngineSpec | None = None,
        **overrides,
    ):
        if spec is None:
            spec = EngineSpec(**overrides)
        elif overrides:
            spec = dataclasses.replace(spec, **overrides)
        self.spec = spec
        if isinstance(semantics, DensityMetric):
            if spec.plane != "host":
                raise TypeError(
                    f"DensityMetric {semantics.name!r} is host-plane-only "
                    "(scalar per-edge hooks); device planes need a "
                    "SuspSemantics — see repro.core.semantics"
                )
            self.semantics: SuspSemantics | DensityMetric = semantics
        else:
            self.semantics = resolve(semantics)

    def run(self, stream: TxStream):
        """Replay ``stream`` through the configured engine.

        Returns a :class:`DeviceServiceReport` (device plane) or a
        :class:`repro.serve.service.ServiceReport` (host plane).
        """
        if self.spec.plane == "host":
            from repro.serve.service import _run_host_service

            return _run_host_service(
                stream,
                metric=self.semantics,
                edge_grouping=self.spec.grouping,
                batch_size=self.spec.effective_batch_edges,
                flush_every=self.spec.flush_every,
            )
        return _run_device_service(stream, self.semantics, self.spec)


# ---------------------------------------------------------------------------
# the device-plane serving loop
# ---------------------------------------------------------------------------


@jax.jit
def _accum_benign(acc, state: DeviceSpadeState, src, dst, c, valid):
    """Device-side benign counter (Def 4.1 against the PRE-tick state);
    padded tail lanes of a partial tick must not count toward stats."""
    return acc + jnp.sum(benign_mask(state, src, dst, c) & valid)


@jax.jit
def _accum_detected(ever, community):
    return ever | community


def _run_device_service(
    stream: TxStream, sem: SuspSemantics, spec: EngineSpec
) -> DeviceServiceReport:
    """Fixed-size batched ticks through the device engines (see module
    docstring); the single definition behind the facade's device plane and
    the legacy ``run_device_service`` shim."""
    n = stream.n_vertices
    m_base = stream.base_src.shape[0]
    m_total = m_base + stream.inc_src.shape[0]
    batch_edges = spec.effective_batch_edges
    window_ticks = spec.window_ticks
    eps, max_rounds = spec.eps, spec.max_rounds
    mesh, shard_axis = spec.mesh, spec.shard_axis
    if window_ticks:
        e_cap = m_base + (window_ticks + 1) * batch_edges
    else:
        e_cap = int(m_total * spec.capacity_slack) + batch_edges

    # the semantics' batch-seeding rule: dyadic-snapped edge weights +
    # vertex priors + the degree state the streaming ticks continue from
    base_aux = np.zeros(m_base) if sem.uses_aux else None
    base_w, in_deg = sem.seed_base(
        stream.base_src, stream.base_dst, stream.base_amt, n, aux=base_aux
    )
    a0 = sem.seed_vertices(n, in_deg, aux=None)

    g = device_graph_from_coo(
        n, stream.base_src, stream.base_dst, base_w, a=a0,
        n_capacity=-(-n // 512) * 512, e_capacity=-(-e_cap // 512) * 512,
    )
    predictive = spec.workset and spec.predictive
    predictor = None
    if mesh is not None:
        g = shard_graph(g, mesh, axis=shard_axis)
        state = init_sharded_state(g, mesh, axis=shard_axis, eps=eps)
        refresh = partial(sharded_full_refresh, mesh=mesh, axis=shard_axis)
        if predictive:
            predictor = BucketPredictor(
                g.n_capacity, g.e_capacity // mesh.shape[shard_axis],
                min_bucket=spec.min_bucket,
            )
            maintain = partial(sharded_insert_and_maintain_predictive,
                               predictor=predictor, mesh=mesh, axis=shard_axis)
            slide = partial(sharded_slide_and_maintain_predictive,
                            predictor=predictor, mesh=mesh, axis=shard_axis)
        elif spec.workset:
            maintain = partial(sharded_insert_and_maintain_auto, mesh=mesh,
                               axis=shard_axis, min_bucket=spec.min_bucket)
            slide = partial(sharded_slide_and_maintain_auto, mesh=mesh,
                            axis=shard_axis, min_bucket=spec.min_bucket)
        else:
            maintain = partial(sharded_insert_and_maintain, mesh=mesh,
                               axis=shard_axis)
            slide = partial(sharded_slide_and_maintain, mesh=mesh,
                            axis=shard_axis)
    else:
        state = init_state(g, eps=eps)
        refresh = full_refresh
        if predictive:
            predictor = BucketPredictor(g.n_capacity, g.e_capacity,
                                        min_bucket=spec.min_bucket)
            maintain = partial(insert_and_maintain_predictive,
                               predictor=predictor)
            slide = partial(slide_and_maintain_predictive,
                            predictor=predictor)
        elif spec.workset:
            maintain = partial(insert_and_maintain_auto,
                               min_bucket=spec.min_bucket)
            slide = partial(slide_and_maintain_auto,
                            min_bucket=spec.min_bucket)
        else:
            maintain = insert_and_maintain
            slide = slide_and_maintain
    deg_dev = jnp.asarray(in_deg, jnp.int32)
    if deg_dev.shape[0] < g.n_capacity:
        deg_dev = jnp.pad(deg_dev, (0, g.n_capacity - deg_dev.shape[0]))

    # the semantics' streamed-tick rule, compiled once for the whole run
    weight_fn = jax.jit(sem.batch_weights)

    n_inc = stream.inc_src.shape[0]
    n_ticks = 0
    n_refresh = 0
    n_expired = 0
    t_total = 0.0
    n_workset = 0
    n_fallback = 0
    n_predicted = 0
    n_miss = 0
    max_suffix_edges = 0
    max_e_bucket = 0
    ring: list[int] = []  # per-tick resident edge counts, oldest first
    benign_acc = jnp.int32(0)  # device accumulator, drained at shutdown
    ever_detected = jnp.zeros(g.n_capacity, bool)  # vertices ever in S^P
    slot_ids = jnp.arange(g.e_capacity, dtype=jnp.int32)
    for i in range(0, n_inc, batch_edges):
        j = min(i + batch_edges, n_inc)
        pad = batch_edges - (j - i)
        bs = np.concatenate([stream.inc_src[i:j], np.zeros(pad, np.int64)])
        bd = np.concatenate([stream.inc_dst[i:j], np.zeros(pad, np.int64)])
        amt = np.concatenate([stream.inc_amt[i:j], np.zeros(pad)])
        valid = np.concatenate([np.ones(j - i, bool), np.zeros(pad, bool)])
        bs_d = jnp.asarray(bs, jnp.int32)
        bd_d = jnp.asarray(bd, jnp.int32)
        valid_d = jnp.asarray(valid)
        aux_d = None
        if sem.uses_aux:
            aux = np.concatenate([stream.inc_time[i:j], np.zeros(pad)])
            aux_d = jnp.asarray(aux, jnp.float32)
        w, deg_dev = weight_fn(
            deg_dev, bs_d, bd_d, jnp.asarray(amt, jnp.float32), valid_d, aux_d
        )
        benign_acc = _accum_benign(benign_acc, state, bs_d, bd_d, w, valid_d)
        t0 = time.perf_counter()
        info = None
        if window_ticks and len(ring) >= window_ticks:
            # fused tick: expire the batch sliding out + insert the new one
            # with a single warm re-peel.  After compaction the oldest
            # resident batch always sits right after the base graph.
            cnt0 = ring.pop(0)
            drop = (slot_ids >= m_base) & (slot_ids < m_base + cnt0)
            kw = {"n_dropped": cnt0} if predictive else {}
            out = slide(
                state, drop, bs_d, bd_d, w.astype(jnp.float32), valid_d,
                eps=eps, max_rounds=max_rounds, **kw,
            )
            state, info = out if spec.workset else (out, None)
            n_expired += cnt0
        else:
            out = maintain(
                state, bs_d, bd_d, w.astype(jnp.float32), valid_d,
                eps=eps, max_rounds=max_rounds,
            )
            state, info = out if spec.workset else (out, None)
        jax.block_until_ready(state.best_g)
        t_total += time.perf_counter() - t0
        if info is not None:
            n_fallback += info.fallback
            n_workset += not info.fallback
            n_predicted += info.predicted
            n_miss += info.miss
            max_suffix_edges = max(max_suffix_edges, info.n_suffix_edges)
            max_e_bucket = max(max_e_bucket, info.e_bucket)
        if window_ticks:
            ring.append(int(valid.sum()))
            # a windowed community is transient by design (the evidence
            # expires); recall is therefore "ever detected while resident",
            # tracked as a device bool vector and drained once at shutdown
            ever_detected = _accum_detected(ever_detected, state.community)
        n_ticks += 1
        if spec.refresh_every and n_ticks % spec.refresh_every == 0:
            state = refresh(state, eps=eps)
            n_refresh += 1

    # drain the device-resident stats once, after the loop
    benign_total = int(benign_acc)
    detected = np.where(np.asarray(ever_detected))[0].tolist()
    comm = set(np.where(np.asarray(state.community))[0].tolist()) | set(detected)
    fraud = set(stream.fraud_block.tolist())
    recall = len(fraud & comm) / len(fraud) if fraud else 1.0
    return DeviceServiceReport(
        n_edges=n_inc,
        n_ticks=n_ticks,
        mean_tick_seconds=t_total / max(n_ticks, 1),
        mean_us_per_edge=1e6 * t_total / max(n_inc, 1),
        benign_fraction=benign_total / max(n_inc, 1),
        fraud_recall=recall,
        final_g=float(state.best_g),
        n_refreshes=n_refresh,
        window_ticks=window_ticks,
        n_expired_edges=n_expired,
        live_edges=int(state.edge_count),
        n_workset_ticks=n_workset,
        n_fallback_ticks=n_fallback,
        max_suffix_edges=max_suffix_edges,
        max_e_bucket=max_e_bucket,
        n_predicted_ticks=n_predicted,
        n_bucket_miss_ticks=n_miss,
    )
