from .device_service import run_device_service
from .service import ServiceReport, run_service
from .spade_service import DeviceServiceReport, EngineSpec, SpadeService

__all__ = ["SpadeService", "EngineSpec", "ServiceReport",
           "DeviceServiceReport", "run_service", "run_device_service"]
