from .device_service import DeviceServiceReport, run_device_service
from .service import ServiceReport, run_service

__all__ = ["ServiceReport", "run_service", "DeviceServiceReport",
           "run_device_service"]
