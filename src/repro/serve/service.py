"""Streaming fraud-detection service (the paper's end-to-end deployment).

Replays a timestamped transaction stream (``repro.graphstore.generators``)
through Spade with edge grouping (§4.3) and measures the paper's §5
metrics:

* **latency** L(ΔG^τ) (Eq. 4): response time per fraudulent edge =
  (reorder completion time) - (edge generation time), queueing included.
* **prevention ratio** R: fraction of a fraud burst's edges arriving
  *after* the fraudster was first detected (those are blockable).

This module holds the host-plane serving loop; the public entrypoint of
record is :class:`repro.serve.SpadeService` with ``EngineSpec(plane=
"host")`` — :func:`run_service` remains as a deprecation shim.
"""

from __future__ import annotations

import time
import warnings
from dataclasses import dataclass

import numpy as np

from repro._warnings import SpadeDeprecationWarning
from repro.core.metrics import DensityMetric
from repro.core.semantics import SuspSemantics
from repro.core.spade import Spade
from repro.graphstore.generators import TxStream

__all__ = ["ServiceReport", "run_service"]


@dataclass
class ServiceReport:
    n_edges: int
    n_reorders: int
    n_buffered_flushes: int
    total_reorder_seconds: float
    mean_us_per_edge: float
    detection_edge_index: int | None  # stream index when fraud block detected
    detection_latency_s: float | None  # sim-time lag behind the first fraud edge
    prevention_ratio: float | None
    fraud_recall: float  # fraction of planted fraudsters in final community
    wall_seconds: float


def run_service(
    stream: TxStream,
    metric: DensityMetric | SuspSemantics | str = "DW",
    edge_grouping: bool = True,
    batch_size: int = 1,
    flush_every: float = 1.0,
    time_scale: float = 0.0,
) -> ServiceReport:
    """DEPRECATED shim: use ``SpadeService(semantics, EngineSpec(
    plane="host", grouping=..., batch_edges=..., flush_every=...))``."""
    warnings.warn(
        "run_service is deprecated; use repro.serve.SpadeService with "
        "EngineSpec(plane='host')",
        SpadeDeprecationWarning,
        stacklevel=2,
    )
    return _run_host_service(stream, metric=metric,
                             edge_grouping=edge_grouping,
                             batch_size=batch_size, flush_every=flush_every)


def _run_host_service(
    stream: TxStream,
    metric: DensityMetric | SuspSemantics | str = "DW",
    edge_grouping: bool = True,
    batch_size: int = 1,
    flush_every: float = 1.0,
) -> ServiceReport:
    """Replay ``stream`` and report latency/prevention metrics.

    ``batch_size``: edges per InsertBatchEdges call (paper's |ΔE|);
    ``flush_every``: simulated seconds between forced buffer flushes
    (the batch tick when grouping is on).
    """
    sp = Spade(metric=metric, edge_grouping=edge_grouping)
    sp.LoadGraph(stream.base_src, stream.base_dst, stream.base_amt,
                 n_vertices=stream.n_vertices)

    fraud_set = set(stream.fraud_block.tolist())
    fraud_times = stream.inc_time[stream.fraud_label]
    first_fraud_t = float(fraud_times.min()) if fraud_times.size else None

    n = stream.inc_src.shape[0]
    detected_at_idx: int | None = None
    detected_at_t: float | None = None
    total_reorder = 0.0
    n_reorders = 0
    n_flushes = 0
    next_flush = float(stream.inc_time[0]) + flush_every if n else 0.0
    t_wall0 = time.perf_counter()

    i = 0
    while i < n:
        j = min(i + batch_size, n)
        batch = [
            (int(stream.inc_src[k]), int(stream.inc_dst[k]), float(stream.inc_amt[k]))
            for k in range(i, j)
        ]
        sim_t = float(stream.inc_time[j - 1])
        res = sp.InsertBatchEdges(batch)
        if res.triggered:
            n_reorders += 1
            total_reorder += res.reorder_seconds
        if sim_t >= next_flush:
            fr = sp.FlushBuffer()
            if fr.triggered:
                n_flushes += 1
                total_reorder += fr.reorder_seconds
            next_flush += flush_every
        if detected_at_idx is None:
            comm, _ = (res.fraudsters, res.g_best) if res.triggered else sp.Detect()
            hit = len(fraud_set & set(comm.tolist()))
            if fraud_set and hit >= 0.8 * len(fraud_set):
                detected_at_idx = j - 1
                detected_at_t = sim_t
        i = j

    sp.FlushBuffer()
    comm, _ = sp.Detect()
    recall = (
        len(fraud_set & set(comm.tolist())) / len(fraud_set) if fraud_set else 1.0
    )
    prevention = None
    latency = None
    if detected_at_t is not None and fraud_times.size:
        prevention = float((fraud_times > detected_at_t).sum()) / fraud_times.size
        latency = detected_at_t - first_fraud_t
    return ServiceReport(
        n_edges=n,
        n_reorders=n_reorders,
        n_buffered_flushes=n_flushes,
        total_reorder_seconds=total_reorder,
        mean_us_per_edge=1e6 * total_reorder / max(n, 1),
        detection_edge_index=detected_at_idx,
        detection_latency_s=latency,
        prevention_ratio=prevention,
        fraud_recall=recall,
        wall_seconds=time.perf_counter() - t_wall0,
    )
