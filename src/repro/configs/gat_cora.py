"""gat-cora [gnn]: 2L d_hidden=8 n_heads=8 attention aggregator
[arXiv:1710.10903; paper]."""
from repro.configs.base import GNNConfig

CONFIG = GNNConfig(
    name="gat-cora", kind="gat", n_layers=2, d_hidden=8, n_heads=8,
    d_feat=0, aggregator="attn", n_classes=7,
)
SMOKE_CONFIG = GNNConfig(
    name="gat-cora-smoke", kind="gat", n_layers=2, d_hidden=4, n_heads=2,
    d_feat=8, aggregator="attn", n_classes=4,
)
