"""spade-grab: the paper's own workload — evolving-graph dense-subgraph
maintenance at Grab4 scale (6.02M vertices / 25M base edges + 2.5M
increments, Table 3), as a device-plane streaming cell."""
from repro.configs.base import SpadeConfig

# max_rounds: bulk peeling converges in 5-7 rounds on power-law graphs from
# 20k to 400k edges with planted dense blocks (measured; EXPERIMENTS §Perf) —
# 20 gives ~3x headroom at Grab scale; unconverged vertices take the final
# round's level and the periodic full_refresh (exact while_loop) corrects.
CONFIG = SpadeConfig(
    name="spade-grab", n_capacity=6_023_000, e_capacity=27_500_000,
    batch_edges=4096, eps=0.1, max_rounds=20,
)
SMOKE_CONFIG = SpadeConfig(
    name="spade-grab-smoke", n_capacity=512, e_capacity=4096,
    batch_edges=64, eps=0.1, max_rounds=16,
)
