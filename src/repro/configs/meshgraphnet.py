"""meshgraphnet [gnn]: 15 processor blocks, d_hidden=128, sum aggregator,
2-layer MLPs [arXiv:2010.03409]."""
from repro.configs.base import GNNConfig

CONFIG = GNNConfig(
    name="meshgraphnet", kind="meshgraphnet", n_layers=15, d_hidden=128,
    d_feat=0, aggregator="sum", mlp_layers=2,
)
SMOKE_CONFIG = GNNConfig(
    name="meshgraphnet-smoke", kind="meshgraphnet", n_layers=3, d_hidden=16,
    d_feat=8, aggregator="sum", mlp_layers=2, n_classes=4,
)
