"""Architecture registry: ``--arch <id>`` ids map to config modules.

``get_config(arch)`` -> full config; ``get_smoke_config(arch)`` -> reduced
same-family config; ``ARCH_FAMILY`` -> 'lm' | 'gnn' | 'recsys' | 'spade';
``arch_shapes(arch)`` -> {shape_name: ShapeSpec | SkipReason}.
"""

from __future__ import annotations

import importlib
from dataclasses import dataclass

from repro.configs.base import (
    GNN_SHAPES,
    LM_SHAPES,
    RECSYS_SHAPES,
    SPADE_SHAPES,
    GNNConfig,
    LMConfig,
    MoESpec,
    RecsysConfig,
    ShapeSpec,
    SpadeConfig,
)

__all__ = [
    "ARCHS",
    "ARCH_FAMILY",
    "get_config",
    "get_smoke_config",
    "arch_shapes",
    "Skip",
    "all_cells",
]

_MODULES = {
    "mixtral-8x7b": "mixtral_8x7b",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "internlm2-20b": "internlm2_20b",
    "deepseek-coder-33b": "deepseek_coder_33b",
    "qwen3-14b": "qwen3_14b",
    "meshgraphnet": "meshgraphnet",
    "gat-cora": "gat_cora",
    "dimenet": "dimenet",
    "gcn-cora": "gcn_cora",
    "two-tower-retrieval": "two_tower_retrieval",
    "spade-grab": "spade_grab",
}

ARCHS = tuple(_MODULES)

ARCH_FAMILY = {
    "mixtral-8x7b": "lm",
    "olmoe-1b-7b": "lm",
    "internlm2-20b": "lm",
    "deepseek-coder-33b": "lm",
    "qwen3-14b": "lm",
    "meshgraphnet": "gnn",
    "gat-cora": "gnn",
    "dimenet": "gnn",
    "gcn-cora": "gnn",
    "two-tower-retrieval": "recsys",
    "spade-grab": "spade",
}


@dataclass(frozen=True)
class Skip:
    reason: str


def _module(arch: str):
    return importlib.import_module(f"repro.configs.{_MODULES[arch]}")


def get_config(arch: str):
    return _module(arch).CONFIG


def get_smoke_config(arch: str):
    return _module(arch).SMOKE_CONFIG


def arch_shapes(arch: str) -> dict[str, ShapeSpec | Skip]:
    fam = ARCH_FAMILY[arch]
    if fam == "lm":
        cfg: LMConfig = get_config(arch)
        out: dict[str, ShapeSpec | Skip] = dict(LM_SHAPES)
        if cfg.sliding_window is None:
            # long_500k requires sub-quadratic attention; pure full-attention
            # archs skip it (DESIGN.md §4) — SWA archs (mixtral) run it.
            out["long_500k"] = Skip(
                "full-attention arch: 524288-token dense KV cache is not "
                "sub-quadratic; SWA/SSM archs only"
            )
        return out
    if fam == "gnn":
        return dict(GNN_SHAPES)
    if fam == "recsys":
        return dict(RECSYS_SHAPES)
    if fam == "spade":
        return dict(SPADE_SHAPES)
    raise KeyError(arch)


def all_cells(include_spade: bool = True):
    """Every (arch, shape) cell — 40 assigned + the paper's own workload."""
    cells = []
    for arch in ARCHS:
        if ARCH_FAMILY[arch] == "spade" and not include_spade:
            continue
        for shape, spec in arch_shapes(arch).items():
            cells.append((arch, shape, spec))
    return cells
