"""internlm2-20b [dense]: 48L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=92544 [arXiv:2403.17297; hf]."""
from repro.configs.base import LMConfig

CONFIG = LMConfig(
    name="internlm2-20b", n_layers=48, d_model=6144, n_heads=48, n_kv_heads=8,
    d_head=128, d_ff=16384, vocab=92544, rope_theta=1e6,
)
SMOKE_CONFIG = LMConfig(
    name="internlm2-20b-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_head=16, d_ff=128, vocab=128, dtype="float32",
)
