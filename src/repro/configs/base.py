"""Config dataclasses for every architecture family + shape specs.

Every assigned architecture gets a module in this package exposing
``CONFIG`` (full-size, exercised only via the dry-run) and
``SMOKE_CONFIG`` (reduced same-family config for CPU smoke tests).
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Literal

__all__ = [
    "MoESpec",
    "LMConfig",
    "GNNConfig",
    "RecsysConfig",
    "SpadeConfig",
    "ShapeSpec",
    "LM_SHAPES",
    "GNN_SHAPES",
    "RECSYS_SHAPES",
    "SPADE_SHAPES",
]


@dataclass(frozen=True)
class MoESpec:
    n_experts: int
    top_k: int
    d_ff_expert: int
    capacity_factor: float = 1.25
    # EP when E divides the 'model' mesh axis (olmoe: 64 experts); otherwise
    # TP-on-d_ff inside each expert (mixtral: 8 experts < 16 shards)
    expert_parallel: bool = True
    # §Perf virtual experts: split each expert's d_ff into `virtual_split`
    # shards stacked on the expert axis so E*vs == model-axis size — expert
    # weights stay resident (EPxTP) instead of being FSDP-gathered per layer
    virtual_split: int = 1


@dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    d_ff: int  # dense FFN width (ignored when moe is set)
    vocab: int
    moe: MoESpec | None = None
    qk_norm: bool = False
    sliding_window: int | None = None
    rope_theta: float = 1e6
    dtype: str = "bfloat16"
    # attention blocking (roofline-tunable)
    q_block: int = 512
    kv_block: int = 1024
    # roofline lowering mode: python-unrolled scans (exact FLOP accounting)
    unroll: bool = False

    @property
    def n_params(self) -> int:
        """Total parameter count (embedding + layers + head)."""
        D, F, V, L = self.d_model, self.d_ff, self.vocab, self.n_layers
        attn = D * (self.n_heads * self.d_head) * 2 + D * (
            self.n_kv_heads * self.d_head
        ) * 2
        if self.moe:
            ffn = self.moe.n_experts * 3 * D * self.moe.d_ff_expert + D * self.moe.n_experts
        else:
            ffn = 3 * D * F
        norms = 2 * D + (2 * self.d_head if self.qk_norm else 0)
        return V * D * 2 + L * (attn + ffn + norms) + D

    @property
    def n_active_params(self) -> int:
        """Active parameters per token (MoE top-k)."""
        if not self.moe:
            return self.n_params
        D, L = self.d_model, self.n_layers
        attn = D * (self.n_heads * self.d_head) * 2 + D * (
            self.n_kv_heads * self.d_head
        ) * 2
        ffn = self.moe.top_k * 3 * D * self.moe.d_ff_expert + D * self.moe.n_experts
        return self.vocab * D * 2 + L * (attn + ffn + 2 * D) + D


@dataclass(frozen=True)
class GNNConfig:
    name: str
    kind: Literal["gcn", "gat", "meshgraphnet", "dimenet"]
    n_layers: int
    d_hidden: int
    d_feat: int  # input feature dim (overridden per shape)
    n_classes: int = 16
    n_heads: int = 1  # gat
    aggregator: str = "sum"
    mlp_layers: int = 2  # meshgraphnet
    n_bilinear: int = 8  # dimenet
    n_spherical: int = 7
    n_radial: int = 6
    triplet_cap_per_edge: int = 4  # dimenet subsampled triplets at scale
    dtype: str = "float32"
    unroll: bool = False  # roofline lowering mode


@dataclass(frozen=True)
class RecsysConfig:
    name: str
    embed_dim: int = 256
    tower_mlp: tuple[int, ...] = (1024, 512, 256)
    n_user_fields: int = 8
    n_item_fields: int = 4
    user_vocab: int = 50_000_000
    item_vocab: int = 10_000_000
    multi_hot: int = 16  # lookups per bag (user history etc.)
    interaction: str = "dot"
    dtype: str = "float32"


@dataclass(frozen=True)
class SpadeConfig:
    """The paper's own workload: evolving-graph peeling at Grab scale."""

    name: str
    n_capacity: int
    e_capacity: int
    batch_edges: int = 4096
    eps: float = 0.1
    max_rounds: int = 64


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: Literal["train", "prefill", "decode", "graph_full", "graph_mini", "graph_batch",
                  "recsys_train", "recsys_serve", "retrieval", "spade_stream", "spade_static"]
    # LM
    seq_len: int = 0
    global_batch: int = 0
    # GNN
    n_nodes: int = 0
    n_edges: int = 0
    d_feat: int = 0
    batch_nodes: int = 0
    fanout: tuple[int, ...] = ()
    n_graphs: int = 0
    # recsys
    batch: int = 0
    n_candidates: int = 0


LM_SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", seq_len=4096, global_batch=256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", seq_len=32768, global_batch=32),
    "decode_32k": ShapeSpec("decode_32k", "decode", seq_len=32768, global_batch=128),
    "long_500k": ShapeSpec("long_500k", "decode", seq_len=524288, global_batch=1),
}

GNN_SHAPES = {
    "full_graph_sm": ShapeSpec(
        "full_graph_sm", "graph_full", n_nodes=2708, n_edges=10556, d_feat=1433
    ),
    "minibatch_lg": ShapeSpec(
        "minibatch_lg",
        "graph_mini",
        n_nodes=232965,
        n_edges=114615892,
        batch_nodes=1024,
        fanout=(15, 10),
        d_feat=602,
    ),
    "ogb_products": ShapeSpec(
        "ogb_products", "graph_full", n_nodes=2449029, n_edges=61859140, d_feat=100
    ),
    "molecule": ShapeSpec(
        "molecule", "graph_batch", n_nodes=30, n_edges=64, n_graphs=128, d_feat=32
    ),
}

RECSYS_SHAPES = {
    "train_batch": ShapeSpec("train_batch", "recsys_train", batch=65536),
    "serve_p99": ShapeSpec("serve_p99", "recsys_serve", batch=512),
    "serve_bulk": ShapeSpec("serve_bulk", "recsys_serve", batch=262144),
    "retrieval_cand": ShapeSpec(
        "retrieval_cand", "retrieval", batch=1, n_candidates=1_000_000
    ),
}

SPADE_SHAPES = {
    "grab4_static": ShapeSpec("grab4_static", "spade_static", n_nodes=6_023_000,
                              n_edges=25_000_000),
    "grab4_stream": ShapeSpec("grab4_stream", "spade_stream", n_nodes=6_023_000,
                              n_edges=27_500_000),
}
