"""mixtral-8x7b [moe]: 32L d_model=4096 32H (GQA kv=8) MoE 8e top-2
d_ff_expert=14336 vocab=32000, sliding-window attention (W=4096)
[arXiv:2401.04088; hf]."""
from repro.configs.base import LMConfig, MoESpec

CONFIG = LMConfig(
    name="mixtral-8x7b", n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_head=128, d_ff=0, vocab=32000,
    moe=MoESpec(n_experts=8, top_k=2, d_ff_expert=14336, expert_parallel=True,
                virtual_split=2),  # §Perf: 8 experts x 2-way d_ff split = 16 EP shards
    sliding_window=4096, rope_theta=1e6,
)
SMOKE_CONFIG = LMConfig(
    name="mixtral-8x7b-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_head=16, d_ff=0, vocab=128, moe=MoESpec(n_experts=4, top_k=2, d_ff_expert=96, expert_parallel=True, virtual_split=2),
    sliding_window=16, dtype="float32",
)
