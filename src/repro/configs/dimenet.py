"""dimenet [gnn]: 6 interaction blocks, d_hidden=128, n_bilinear=8,
n_spherical=7, n_radial=6 [arXiv:2003.03123].  Triplets are capped per
edge at scale (GemNet-style subsampling; DESIGN.md 4)."""
from repro.configs.base import GNNConfig

CONFIG = GNNConfig(
    name="dimenet", kind="dimenet", n_layers=6, d_hidden=128, d_feat=0,
    n_bilinear=8, n_spherical=7, n_radial=6, triplet_cap_per_edge=4,
)
SMOKE_CONFIG = GNNConfig(
    name="dimenet-smoke", kind="dimenet", n_layers=2, d_hidden=16, d_feat=8,
    n_bilinear=4, n_spherical=3, n_radial=4, triplet_cap_per_edge=3, n_classes=4,
)
