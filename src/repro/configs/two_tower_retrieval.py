"""two-tower-retrieval [recsys]: embed_dim=256, tower MLP 1024-512-256,
dot interaction, sampled-softmax retrieval [RecSys'19 (YouTube)].
Embedding tables row-sharded over ('data','model')."""
from repro.configs.base import RecsysConfig

# vocabs padded to multiples of 256 so the row-sharded tables divide the
# ('data','model') axes exactly (50M / 10M rounded up by 128 rows)
CONFIG = RecsysConfig(
    name="two-tower-retrieval", embed_dim=256, tower_mlp=(1024, 512, 256),
    n_user_fields=8, n_item_fields=4, user_vocab=50_000_128,
    item_vocab=10_000_128, multi_hot=16,
)
SMOKE_CONFIG = RecsysConfig(
    name="two-tower-retrieval-smoke", embed_dim=16, tower_mlp=(32, 16),
    n_user_fields=3, n_item_fields=2, user_vocab=1000, item_vocab=500,
    multi_hot=4,
)
