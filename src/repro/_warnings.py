"""Warning categories for the repro package.

Kept import-light on purpose: ``python -W error::repro._warnings.SpadeDeprecationWarning``
resolves the category at interpreter start, before jax is importable cheaply,
so this module must not pull in anything heavy.
"""

__all__ = ["SpadeDeprecationWarning"]


class SpadeDeprecationWarning(DeprecationWarning):
    """Raised by the legacy string/flag entrypoints (``metric: str``
    parameters, ``run_service``/``run_device_service``) that the
    ``SuspSemantics`` + ``SpadeService`` API replaces.

    Deprecation policy: the shims stay source-compatible for existing
    callers and tests; first-party code (examples, benchmarks, the CLI)
    must not trigger them — CI's example-smoke lane runs with this
    category escalated to an error.
    """
