"""GNN zoo: GCN, GAT, MeshGraphNet, DimeNet — all built on the segment-op
substrate (JAX has no sparse SpMM; message passing is gather -> segment
reduce, the contract shared with the Pallas ``gather_segsum`` kernel).

Fixed-shape contract: every graph batch is a :class:`GraphBatch` with
static array sizes (padded); batched small graphs (``molecule``) are the
same code path via block-diagonal edge indices.  DimeNet additionally takes
host-precomputed triplet indices (k->j, j->i) with a per-edge cap
(GemNet-style subsampling — unbounded triplets are Θ(Σ deg²); see
DESIGN.md §4).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import GNNConfig
from repro.dist.sharding import constrain
from repro.graphstore.segment_ops import (
    gather_scatter_sum,
    segment_mean,
    segment_softmax,
    segment_sum,
)
from repro.models.layers import Initializer, maybe_scan

__all__ = ["GraphBatch", "init_gnn_params", "gnn_forward", "gnn_loss", "make_triplets"]


class GraphBatch(NamedTuple):
    """Static-shape graph inputs.

    ``edge_src/edge_dst`` index ``node_feat``; padding edges point at node
    ``N-1`` with ``edge_mask = False``.  DimeNet fields may be zero-sized
    for other models.
    """

    node_feat: jax.Array  # [N, F] f32
    edge_src: jax.Array  # [E] i32
    edge_dst: jax.Array  # [E] i32
    edge_mask: jax.Array  # [E] bool
    node_mask: jax.Array  # [N] bool
    edge_feat: jax.Array  # [E, Fe] f32 (meshgraphnet; else [E, 0])
    labels: jax.Array  # [N] i32 node labels (or graph labels via seg ids)
    # dimenet triplets: edge k->j feeds edge j->i with interior angle
    tri_in: jax.Array  # [T] i32 edge id (k->j)
    tri_out: jax.Array  # [T] i32 edge id (j->i)
    tri_angle: jax.Array  # [T] f32 angle
    tri_mask: jax.Array  # [T] bool
    edge_len: jax.Array  # [E] f32 distances (dimenet)


def _mlp_params(init: Initializer, dims: list[int], dt) -> dict:
    return {
        f"w{i}": init((a, b), fan_in=a, dtype=dt)
        for i, (a, b) in enumerate(zip(dims[:-1], dims[1:]))
    } | {f"b{i}": jnp.zeros((b,), dt) for i, b in enumerate(dims[1:])}


def _mlp(p: dict, x: jax.Array, n: int, act=jax.nn.relu, final_act=False) -> jax.Array:
    for i in range(n):
        x = x @ p[f"w{i}"] + p[f"b{i}"]
        if i < n - 1 or final_act:
            x = act(x)
    return x


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def init_gnn_params(key: jax.Array, cfg: GNNConfig, d_feat: int, d_edge_feat: int = 4) -> dict:
    init = Initializer(key)
    dt = jnp.dtype(cfg.dtype)
    H = cfg.d_hidden
    if cfg.kind == "gcn":
        dims = [d_feat] + [H] * (cfg.n_layers - 1) + [cfg.n_classes]
        return {
            "w": [init((a, b), fan_in=a, dtype=dt) for a, b in zip(dims[:-1], dims[1:])],
            "b": [jnp.zeros((b,), dt) for b in dims[1:]],
        }
    if cfg.kind == "gat":
        heads = cfg.n_heads
        p = {"layers": []}
        d_in = d_feat
        for li in range(cfg.n_layers):
            last = li == cfg.n_layers - 1
            d_out = cfg.n_classes if last else H
            p["layers"].append(
                {
                    "w": init((d_in, heads * d_out), fan_in=d_in, dtype=dt),
                    "a_src": init((heads, d_out), fan_in=d_out, dtype=dt),
                    "a_dst": init((heads, d_out), fan_in=d_out, dtype=dt),
                }
            )
            d_in = d_out if last else heads * d_out
        return p
    if cfg.kind == "meshgraphnet":
        L, n_mlp = cfg.n_layers, cfg.mlp_layers
        enc_node = _mlp_params(init, [d_feat] + [H] * n_mlp, dt)
        enc_edge = _mlp_params(init, [d_edge_feat] + [H] * n_mlp, dt)
        # stacked processor blocks (leading dim L) for lax.scan
        def stack(dims):
            ps = [_mlp_params(Initializer(jax.random.fold_in(key, 100 + i)), dims, dt) for i in range(L)]
            return jax.tree.map(lambda *xs: jnp.stack(xs), *ps)

        proc_edge = stack([3 * H] + [H] * n_mlp)
        proc_node = stack([2 * H] + [H] * n_mlp)
        dec = _mlp_params(init, [H] * n_mlp + [cfg.n_classes], dt)
        return {
            "enc_node": enc_node,
            "enc_edge": enc_edge,
            "proc_edge": proc_edge,
            "proc_node": proc_node,
            "dec": dec,
        }
    if cfg.kind == "dimenet":
        B, H_ = cfg.n_layers, H  # n_layers carries n_blocks for dimenet
        nr, ns, nb = cfg.n_radial, cfg.n_spherical, cfg.n_bilinear
        def stack(maker):
            ps = [maker(Initializer(jax.random.fold_in(key, 200 + i))) for i in range(B)]
            return jax.tree.map(lambda *xs: jnp.stack(xs), *ps)

        return {
            "embed_node": init((d_feat, H_), fan_in=d_feat, dtype=dt),
            "embed_rbf": init((nr, H_), fan_in=nr, dtype=dt),
            "blocks": stack(
                lambda it: {
                    "w_sbf": it((ns * nr, nb), fan_in=ns * nr, dtype=dt),
                    "w_bil": it((nb, H_, H_), fan_in=H_, dtype=dt),
                    "w_msg": it((H_, H_), fan_in=H_, dtype=dt),
                    "w_rbf": it((nr, H_), fan_in=nr, dtype=dt),
                    "w_out1": it((H_, H_), fan_in=H_, dtype=dt),
                    "w_out2": it((H_, H_), fan_in=H_, dtype=dt),
                }
            ),
            "out": _mlp_params(init, [H_, H_, cfg.n_classes], dt),
        }
    raise ValueError(cfg.kind)


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------


def _gcn_forward(p, g: GraphBatch, cfg: GNNConfig):
    N = g.node_feat.shape[0]
    ones = jnp.where(g.edge_mask, 1.0, 0.0)
    deg = segment_sum(ones, g.edge_dst, N) + segment_sum(ones, g.edge_src, N) + 1.0
    inv_sqrt = jax.lax.rsqrt(deg)
    x = g.node_feat
    for i, (w, b) in enumerate(zip(p["w"], p["b"])):
        h = x @ w + b
        # symmetric-normalized aggregation over both directions + self loop
        ew = jnp.where(g.edge_mask, inv_sqrt[g.edge_src] * inv_sqrt[g.edge_dst], 0.0)
        agg = gather_scatter_sum(h, g.edge_src, g.edge_dst, N, edge_weight=ew)
        agg = agg + gather_scatter_sum(h, g.edge_dst, g.edge_src, N, edge_weight=ew)
        x = agg + h * (inv_sqrt * inv_sqrt)[:, None]
        if cfg.aggregator == "mean":
            pass  # sym-norm already averages
        if i < len(p["w"]) - 1:
            x = jax.nn.relu(x)
    return x


def _gat_forward(p, g: GraphBatch, cfg: GNNConfig):
    N = g.node_feat.shape[0]
    x = g.node_feat
    E = g.edge_src.shape[0]
    for li, lp in enumerate(p["layers"]):
        last = li == len(p["layers"]) - 1
        heads = cfg.n_heads
        d_out = lp["a_src"].shape[1]
        h = (x @ lp["w"]).reshape(N, heads, d_out)
        es = jnp.einsum("nhd,hd->nh", h, lp["a_src"])
        ed = jnp.einsum("nhd,hd->nh", h, lp["a_dst"])
        logits = jax.nn.leaky_relu(es[g.edge_src] + ed[g.edge_dst], 0.2)  # [E, H]
        logits = jnp.where(g.edge_mask[:, None], logits, -1e30)
        alpha = segment_softmax(logits, g.edge_dst, N)  # [E, H]
        msgs = h[g.edge_src] * alpha[..., None]  # [E, H, D]
        agg = segment_sum(
            jnp.where(g.edge_mask[:, None, None], msgs, 0.0), g.edge_dst, N
        )
        x = agg.mean(axis=1) if last else jax.nn.elu(agg.reshape(N, heads * d_out))
    return x


def _layer_norm(x, eps=1e-6):
    mu = x.mean(-1, keepdims=True)
    var = ((x - mu) ** 2).mean(-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps)


def _mgn_forward(p, g: GraphBatch, cfg: GNNConfig):
    N = g.node_feat.shape[0]
    n = cfg.mlp_layers
    # MGN convention: every MLP (except the decoder) is LayerNorm'd
    h = _layer_norm(_mlp(p["enc_node"], g.node_feat, n, final_act=True))
    e = _layer_norm(_mlp(p["enc_edge"], g.edge_feat, n, final_act=True))
    em = g.edge_mask[:, None]

    def step(carry, lp):
        h, e = carry
        pe, pn = lp
        e_in = jnp.concatenate([e, h[g.edge_src], h[g.edge_dst]], axis=-1)
        e = e + jnp.where(em, _layer_norm(_mlp(pe, e_in, n)), 0.0)
        e = constrain(e, "edges", None)
        agg = segment_sum(jnp.where(em, e, 0.0), g.edge_dst, N)
        if cfg.aggregator == "mean":
            agg = segment_mean(jnp.where(em, e, 0.0), g.edge_dst, N)
        h = h + _layer_norm(_mlp(pn, jnp.concatenate([h, agg], axis=-1), n))
        h = constrain(h, "vertex", None)
        return (h, e), None

    # remat: store only the (h, e) carries across the 15 processor steps;
    # the step MLP intermediates ([E, 3H] concats etc.) are recomputed in
    # the backward pass — without this, ogb_products stores ~95 GB/step
    # (bf16 carries were tried and refuted: no temp change under the CPU
    # buffer model; kept f32 for clean numerics)
    (h, _e), _ = maybe_scan(jax.checkpoint(step), (h, e),
                            (p["proc_edge"], p["proc_node"]), unroll=cfg.unroll)
    return _mlp(p["dec"], h, n)


def _radial_basis(d, n_radial, cutoff=5.0):
    n = jnp.arange(1, n_radial + 1, dtype=jnp.float32)
    d = jnp.maximum(d, 1e-6)[:, None]
    return jnp.sqrt(2.0 / cutoff) * jnp.sin(n * jnp.pi * d / cutoff) / d


def _spherical_basis(angle, d, n_spherical, n_radial, cutoff=5.0):
    # separable Fourier-Bessel-flavoured basis: cos(l*theta) * sin(n*pi*d/c)/d
    l = jnp.arange(n_spherical, dtype=jnp.float32)
    n = jnp.arange(1, n_radial + 1, dtype=jnp.float32)
    ang = jnp.cos(l[None, :] * angle[:, None])  # [T, S]
    dd = jnp.maximum(d, 1e-6)[:, None]
    rad = jnp.sin(n * jnp.pi * dd / cutoff) / dd  # [T, R]
    return (ang[:, :, None] * rad[:, None, :]).reshape(angle.shape[0], -1)  # [T, S*R]


def _dimenet_forward(p, g: GraphBatch, cfg: GNNConfig):
    N, E = g.node_feat.shape[0], g.edge_src.shape[0]
    H = cfg.d_hidden
    rbf = _radial_basis(g.edge_len, cfg.n_radial)  # [E, R]
    x = g.node_feat @ p["embed_node"]  # [N, H]
    m = jax.nn.silu(x[g.edge_src] + x[g.edge_dst] + rbf @ p["embed_rbf"])  # [E, H]
    sbf = _spherical_basis(g.tri_angle, g.edge_len[g.tri_out], cfg.n_spherical, cfg.n_radial)

    def block(m, bp):
        # directional message passing over triplets k->j->i
        m_kj = m[g.tri_in] @ bp["w_msg"]  # [T, H]
        basis = sbf @ bp["w_sbf"]  # [T, B]
        inter = jnp.einsum("tb,bhf,th->tf", basis, bp["w_bil"], m_kj)  # [T, H]
        inter = jnp.where(g.tri_mask[:, None], inter, 0.0)
        agg = segment_sum(inter, g.tri_out, E)  # [E, H]
        m = jax.nn.silu(m + agg + rbf @ bp["w_rbf"])
        out = jax.nn.silu(m @ bp["w_out1"]) @ bp["w_out2"]
        return m, out

    m, outs = maybe_scan(jax.checkpoint(block), m, p["blocks"], unroll=cfg.unroll)
    per_edge = outs.sum(0)  # [E, H]
    per_node = segment_sum(
        jnp.where(g.edge_mask[:, None], per_edge, 0.0), g.edge_dst, N
    )
    return _mlp(p["out"], per_node, 2)


def gnn_forward(p: dict, g: GraphBatch, cfg: GNNConfig) -> jax.Array:
    fn = {
        "gcn": _gcn_forward,
        "gat": _gat_forward,
        "meshgraphnet": _mgn_forward,
        "dimenet": _dimenet_forward,
    }[cfg.kind]
    out = fn(p, g, cfg)
    return constrain(out, "vertex", None)


def gnn_loss(p: dict, g: GraphBatch, cfg: GNNConfig):
    logits = gnn_forward(p, g, cfg)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, g.labels[:, None], axis=-1)[:, 0]
    nll = jnp.where(g.node_mask, lse - ll, 0.0)
    return nll.sum() / jnp.maximum(g.node_mask.sum(), 1), {}


# ---------------------------------------------------------------------------
# host-side triplet construction (dimenet data pipeline)
# ---------------------------------------------------------------------------


def make_triplets(
    src: np.ndarray, dst: np.ndarray, cap_per_edge: int, rng: np.random.Generator
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """For each edge (j->i), sample up to ``cap_per_edge`` incoming edges
    (k->j); returns (tri_in, tri_out, mask) of static size E * cap."""
    E = src.shape[0]
    order = np.argsort(dst, kind="stable")
    indptr = np.zeros(int(max(dst.max(initial=0), src.max(initial=0)) + 2), np.int64)
    np.add.at(indptr, dst + 1, 1)
    np.cumsum(indptr, out=indptr)
    tri_in = np.zeros(E * cap_per_edge, np.int32)
    tri_out = np.zeros(E * cap_per_edge, np.int32)
    mask = np.zeros(E * cap_per_edge, bool)
    for e in range(E):
        j = src[e]
        lo, hi = indptr[j], indptr[j + 1]
        incoming = order[lo:hi]
        incoming = incoming[incoming != e]
        if incoming.shape[0] == 0:
            continue
        take = min(cap_per_edge, incoming.shape[0])
        sel = rng.choice(incoming, size=take, replace=False)
        s = e * cap_per_edge
        tri_in[s : s + take] = sel
        tri_out[s : s + take] = e
        mask[s : s + take] = True
    return tri_in, tri_out, mask
