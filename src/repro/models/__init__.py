from .attention import decode_attention, dense_attention, flash_attention
from .gnn import GraphBatch, gnn_forward, gnn_loss, init_gnn_params, make_triplets
from .transformer import (KVCache, cache_window, decode_step, forward, init_lm_params,
                          lm_loss, prefill)
from .two_tower import (RecsysBatch, init_two_tower_params, item_tower, retrieval_scores,
                        score_pairs, two_tower_loss, user_tower)

__all__ = ["flash_attention", "dense_attention", "decode_attention", "GraphBatch",
           "gnn_forward", "gnn_loss", "init_gnn_params", "make_triplets", "KVCache",
           "cache_window", "decode_step", "forward", "init_lm_params", "lm_loss",
           "prefill", "RecsysBatch", "init_two_tower_params", "user_tower", "item_tower",
           "two_tower_loss", "score_pairs", "retrieval_scores"]
