"""Two-tower retrieval (YouTube/RecSys'19): huge sparse embedding tables ->
EmbeddingBag (gather + segment-sum; JAX has no native EmbeddingBag — built
on the segment-op substrate) -> per-tower MLP 1024-512-256 -> dot product,
trained with in-batch sampled softmax + logQ correction.

Sharding: embedding tables row-sharded over ('data','model') (the 'rows'
logical axis); tower MLPs replicated; batch over ('pod','data').  The
lookup gather over row-sharded tables is GSPMD'd into an all-gather of the
*hit rows only* pattern (collective-permute heavy — a roofline cell worth
watching, see EXPERIMENTS.md).

This model is also the paper-integration point: the transaction stream
that feeds training is filtered by Spade's benign/urgent classifier
(``examples/fraud_aware_recsys.py``).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import RecsysConfig
from repro.dist.sharding import constrain
from repro.graphstore.segment_ops import embedding_bag
from repro.models.layers import Initializer

__all__ = [
    "RecsysBatch",
    "init_two_tower_params",
    "user_tower",
    "item_tower",
    "two_tower_loss",
    "score_pairs",
    "retrieval_scores",
]


class RecsysBatch(NamedTuple):
    """One training batch: multi-hot categorical fields per tower.

    ``user_idx``: [B, Fu, M] int32 lookups (M = multi-hot width);
    ``user_wt``: [B, Fu, M] f32 per-lookup weights (0 = padding).
    """

    user_idx: jax.Array
    user_wt: jax.Array
    item_idx: jax.Array
    item_wt: jax.Array
    log_q: jax.Array  # [B] sampling log-probability of each in-batch item


def init_two_tower_params(key: jax.Array, cfg: RecsysConfig) -> dict:
    init = Initializer(key)
    dt = jnp.dtype(cfg.dtype)
    D = cfg.embed_dim

    def tower(dims):
        return {
            f"w{i}": init((a, b), fan_in=a, dtype=dt)
            for i, (a, b) in enumerate(zip(dims[:-1], dims[1:]))
        } | {f"b{i}": jnp.zeros((b,), dt) for i, b in enumerate(dims[1:])}

    u_in = cfg.n_user_fields * D
    i_in = cfg.n_item_fields * D
    return {
        "user_table": init((cfg.user_vocab, D), fan_in=D, dtype=dt) * 0.05,
        "item_table": init((cfg.item_vocab, D), fan_in=D, dtype=dt) * 0.05,
        "user_mlp": tower([u_in, *cfg.tower_mlp]),
        "item_mlp": tower([i_in, *cfg.tower_mlp]),
        "temp": jnp.asarray(20.0, dt),
    }


def _bag(table, idx, wt, D):
    """[B, F, M] lookups -> [B, F*D] concatenated bag embeddings."""
    B, F, M = idx.shape
    flat_idx = idx.reshape(-1)
    bag_ids = jnp.repeat(jnp.arange(B * F), M)
    out = embedding_bag(table, flat_idx, bag_ids, B * F, weights=wt.reshape(-1))
    return out.reshape(B, F * D)


def _tower(p, x, n_layers):
    for i in range(n_layers):
        x = x @ p[f"w{i}"] + p[f"b{i}"]
        if i < n_layers - 1:
            x = jax.nn.relu(x)
    # L2-normalized embeddings (standard for dot-product retrieval)
    return x / (jnp.linalg.norm(x, axis=-1, keepdims=True) + 1e-9)


def user_tower(params, idx, wt, cfg: RecsysConfig):
    x = _bag(params["user_table"], idx, wt, cfg.embed_dim)
    x = constrain(x, "batch", None)
    return _tower(params["user_mlp"], x, len(cfg.tower_mlp))


def item_tower(params, idx, wt, cfg: RecsysConfig):
    x = _bag(params["item_table"], idx, wt, cfg.embed_dim)
    x = constrain(x, "batch", None)
    return _tower(params["item_mlp"], x, len(cfg.tower_mlp))


def two_tower_loss(params, batch: RecsysBatch, cfg: RecsysConfig):
    """In-batch sampled softmax with logQ correction."""
    u = user_tower(params, batch.user_idx, batch.user_wt, cfg)  # [B, D]
    it = item_tower(params, batch.item_idx, batch.item_wt, cfg)  # [B, D]
    logits = (u @ it.T) * params["temp"]  # [B, B]
    logits = logits - batch.log_q[None, :]  # correct for sampling bias
    logits = constrain(logits, "batch", None)
    labels = jnp.arange(u.shape[0])
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    loss = (lse - ll).mean()
    acc = (logits.argmax(-1) == labels).mean()
    return loss, {"in_batch_acc": acc}


def score_pairs(params, batch: RecsysBatch, cfg: RecsysConfig):
    """Online/offline scoring: one score per (user, item) row."""
    u = user_tower(params, batch.user_idx, batch.user_wt, cfg)
    it = item_tower(params, batch.item_idx, batch.item_wt, cfg)
    return jnp.sum(u * it, axis=-1) * params["temp"]


def retrieval_scores(params, user_idx, user_wt, cand_emb, cfg: RecsysConfig, top_k=100):
    """One query against N precomputed candidate embeddings (batched dot,
    not a loop): returns (top-k scores, indices)."""
    u = user_tower(params, user_idx, user_wt, cfg)  # [1, D]
    scores = (cand_emb @ u[0]) * params["temp"]  # [N]
    return jax.lax.top_k(scores, top_k)
