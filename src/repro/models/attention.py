"""Attention: blocked flash-style (pure jnp, online softmax) + KV-cache
decode.  GQA-grouped, causal and sliding-window masks.

The blocked implementation is the roofline-measured path (the Pallas kernel
in ``repro.kernels.flash_attention`` is the TPU hot path with the same
contract, selected on real hardware).  Memory per step is
O(B * Bq * Hq * Bk) — no S x S score materialization, which is what lets
``prefill_32k`` fit the 16 GB/chip v5e budget.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.models.layers import maybe_scan

__all__ = ["flash_attention", "decode_attention", "dense_attention"]

_NEG = -1e30


def _mask_bias(q_pos, k_pos, causal: bool, window: int | None, kv_len=None):
    """[Bq, Bk] additive bias."""
    ok = jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
    if causal:
        ok &= k_pos[None, :] <= q_pos[:, None]
    if window is not None:
        ok &= k_pos[None, :] > q_pos[:, None] - window
    if kv_len is not None:
        ok &= k_pos[None, :] < kv_len
    return jnp.where(ok, 0.0, _NEG).astype(jnp.float32)


def dense_attention(q, k, v, *, causal=True, window=None):
    """Reference O(S^2)-memory attention (smoke scale / kernel oracle).

    q: [B, Sq, Hkv, G, D]; k, v: [B, Skv, Hkv, D]. Returns [B, Sq, Hkv, G, D].
    """
    B, Sq, Hkv, G, D = q.shape
    Skv = k.shape[1]
    scale = 1.0 / jnp.sqrt(D).astype(jnp.float32)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", q.astype(jnp.float32), k.astype(jnp.float32))
    s = s * scale
    q_pos = jnp.arange(Sq) + (Skv - Sq)  # right-aligned queries
    bias = _mask_bias(q_pos, jnp.arange(Skv), causal, window)
    s = s + bias[None, None, None]
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32)).astype(q.dtype)


@partial(jax.jit, static_argnames=("causal", "window", "q_block", "kv_block", "unroll"))
def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    q_block: int = 512,
    kv_block: int = 1024,
    unroll: bool = False,
):
    """Blocked online-softmax attention.

    q: [B, S, Hkv, G, D] (GQA groups folded in), k/v: [B, S, Hkv, D].
    Scans q blocks (outer) and kv blocks (inner); every (qb, kb) tile is
    computed with masking (baseline; causal tile-skipping is a recorded
    §Perf optimization).
    """
    B, S, Hkv, G, D = q.shape
    Skv = k.shape[1]
    Bq = min(q_block, S)
    Bk = min(kv_block, Skv)
    nQ, nK = -(-S // Bq), -(-Skv // Bk)
    pad_q, pad_k = nQ * Bq - S, nK * Bk - Skv
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    scale = 1.0 / jnp.sqrt(D).astype(jnp.float32)

    qb = q.reshape(B, nQ, Bq, Hkv, G, D).transpose(1, 0, 2, 3, 4, 5)
    kb = k.reshape(B, nK, Bk, Hkv, D).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(B, nK, Bk, Hkv, D).transpose(1, 0, 2, 3, 4)

    def q_step(_, qi_and_idx):
        qi, iq = qi_and_idx
        q_pos = iq * Bq + jnp.arange(Bq)

        def kv_step(carry, kv_and_idx):
            m, l, acc = carry
            ki, vi, ik = kv_and_idx
            k_pos = ik * Bk + jnp.arange(Bk)
            s = (
                jnp.einsum(
                    "bqhgd,bkhd->bqhgk", qi.astype(jnp.float32), ki.astype(jnp.float32)
                )
                * scale
            )
            bias = _mask_bias(q_pos, k_pos, causal, window, kv_len=Skv)
            s = s + bias[None, :, None, None, :]
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bqhgk,bkhd->bqhgd", p, vi.astype(jnp.float32)
            )
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, Bq, Hkv, G), _NEG, jnp.float32)
        l0 = jnp.zeros((B, Bq, Hkv, G), jnp.float32)
        a0 = jnp.zeros((B, Bq, Hkv, G, D), jnp.float32)
        (m, l, acc), _ = maybe_scan(
            kv_step, (m0, l0, a0), (kb, vb, jnp.arange(nK)), unroll=unroll
        )
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return None, out.astype(q.dtype)

    _, out = maybe_scan(q_step, None, (qb, jnp.arange(nQ)), unroll=unroll)
    out = out.transpose(1, 0, 2, 3, 4, 5).reshape(B, nQ * Bq, Hkv, G, D)
    return out[:, :S]


def decode_attention(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    pos: jax.Array,
    *,
    window: int | None = None,
    rolling: bool = False,
):
    """One-token attention over a KV cache.

    q: [B, Hkv, G, D]; caches: [B, W, Hkv, D]; pos: [B] absolute position of
    the query token.  ``rolling`` caches store position t at slot t % W
    (sliding-window serving — the ``long_500k`` path).
    """
    B, W, Hkv, D = k_cache.shape
    scale = 1.0 / jnp.sqrt(D).astype(jnp.float32)
    slots = jnp.arange(W)
    if rolling:
        # absolute position held by each slot given current pos p
        abs_pos = pos[:, None] - ((pos[:, None] - slots[None, :]) % W)
    else:
        abs_pos = jnp.broadcast_to(slots[None, :], (B, W))
    ok = (abs_pos >= 0) & (abs_pos <= pos[:, None])
    if window is not None:
        ok &= abs_pos > pos[:, None] - window
    s = (
        jnp.einsum("bhgd,bshd->bhgs", q.astype(jnp.float32), k_cache.astype(jnp.float32))
        * scale
    )
    s = s + jnp.where(ok, 0.0, _NEG)[:, None, None, :]
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhgs,bshd->bhgd", p, v_cache.astype(jnp.float32)).astype(q.dtype)
