"""Decoder-only transformer LM covering all five assigned LM architectures
(GQA, optional sliding-window attention, optional qk-norm, dense-SwiGLU or
MoE FFN), with scan-over-layers (small HLO, fast multi-pod compiles) and
three entry points:

* ``forward``      — training/scoring forward (causal)
* ``prefill``      — forward + KV-cache construction
* ``decode_step``  — one token with a (optionally rolling) KV cache
"""

from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import LMConfig
from repro.dist.sharding import constrain
from repro.models.attention import decode_attention, flash_attention
from repro.models.layers import (Initializer, apply_rope, maybe_scan, rms_norm,
                                 rope_angles, swiglu)
from repro.models.moe import moe_ffn

__all__ = ["init_lm_params", "forward", "prefill", "decode_step", "lm_loss", "KVCache", "cache_window"]


class KVCache(NamedTuple):
    k: jax.Array  # [L, B, W, Hkv, Dh]
    v: jax.Array  # [L, B, W, Hkv, Dh]


def cache_window(cfg: LMConfig, seq_len: int) -> tuple[int, bool]:
    """(cache width W, rolling?) — SWA models cap the cache at the window."""
    if cfg.sliding_window is not None and cfg.sliding_window < seq_len:
        return cfg.sliding_window, True
    return seq_len, False


def _dtype(cfg: LMConfig):
    return jnp.dtype(cfg.dtype)


def init_lm_params(key: jax.Array, cfg: LMConfig) -> dict:
    init = Initializer(key)
    L, D, V = cfg.n_layers, cfg.d_model, cfg.vocab
    Hq, Hkv, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    dt = _dtype(cfg)
    layers: dict = {
        "attn_norm": jnp.ones((L, D), dt),
        "mlp_norm": jnp.ones((L, D), dt),
        "wq": init((L, D, Hq * Dh), fan_in=D, dtype=dt),
        "wk": init((L, D, Hkv * Dh), fan_in=D, dtype=dt),
        "wv": init((L, D, Hkv * Dh), fan_in=D, dtype=dt),
        "wo": init((L, Hq * Dh, D), fan_in=Hq * Dh, dtype=dt),
    }
    if cfg.qk_norm:
        layers["q_norm"] = jnp.ones((L, Dh), dt)
        layers["k_norm"] = jnp.ones((L, Dh), dt)
    if cfg.moe:
        E, F = cfg.moe.n_experts, cfg.moe.d_ff_expert
        vs = cfg.moe.virtual_split
        Ev, Fv = E * vs, F // vs
        layers["moe"] = {
            "router": init((L, D, E), fan_in=D, dtype=jnp.float32),
            "w_gate": init((L, Ev, D, Fv), fan_in=D, dtype=dt),
            "w_up": init((L, Ev, D, Fv), fan_in=D, dtype=dt),
            "w_down": init((L, Ev, Fv, D), fan_in=F, dtype=dt),
        }
    else:
        F = cfg.d_ff
        layers["mlp"] = {
            "w_gate": init((L, D, F), fan_in=D, dtype=dt),
            "w_up": init((L, D, F), fan_in=D, dtype=dt),
            "w_down": init((L, F, D), fan_in=F, dtype=dt),
        }
    return {
        "embed": init((V, D), fan_in=D, dtype=dt),
        "layers": layers,
        "final_norm": jnp.ones((D,), dt),
        "head": init((D, V), fan_in=D, dtype=dt),
    }


def _attn_block(x, lp, cfg: LMConfig, cos, sin, mode, kc=None, vc=None, pos=None):
    """Shared attention block. Training/prefill: x [B,S,D]; decode: x [B,D]."""
    B = x.shape[0]
    D, Hq, Hkv, Dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    G = Hq // Hkv
    h = rms_norm(x, lp["attn_norm"])
    q = h @ lp["wq"]
    k = h @ lp["wk"]
    v = h @ lp["wv"]
    if mode == "decode":
        q = q.reshape(B, Hkv, G, Dh)
        k = k.reshape(B, Hkv, Dh)
        v = v.reshape(B, Hkv, Dh)
    else:
        S = x.shape[1]
        q = q.reshape(B, S, Hkv, G, Dh)
        k = k.reshape(B, S, Hkv, Dh)
        v = v.reshape(B, S, Hkv, Dh)
    if cfg.qk_norm:
        q = rms_norm(q, lp["q_norm"])
        k = rms_norm(k, lp["k_norm"])
    if mode == "decode":
        q = apply_rope(q, cos[:, None, None, :], sin[:, None, None, :])
        k = apply_rope(k, cos[:, None, :], sin[:, None, :])
        W = kc.shape[1]
        slots = pos % W
        kc = kc.at[jnp.arange(B), slots].set(k)
        vc = vc.at[jnp.arange(B), slots].set(v)
        # the slot invariant (position t lives at slot t % W) makes
        # rolling=True exact for full caches too (W == S_max)
        o = decode_attention(q, kc, vc, pos, window=cfg.sliding_window, rolling=True)
        o = o.reshape(B, Hq * Dh)
        return x + o @ lp["wo"], (kc, vc)
    else:
        q = apply_rope(q, cos[None, :, None, None, :], sin[None, :, None, None, :])
        k = apply_rope(k, cos[None, :, None, :], sin[None, :, None, :])
        if mode == "prefill" and cfg.n_heads % 16 != 0:
            # sequence-sharded serving attention (see dist.sharding 'seq'):
            # measured WIN only when q-heads don't divide the model axis
            # (deepseek 56, qwen3 40: collectives 5-6x down); head-divisible
            # archs (mixtral 32, internlm2 48, olmoe 16) regressed under it
            # and keep the head-sharded path (§Perf cell 5)
            q = constrain(q, "batch", "seq", None, None, None)
            k = constrain(k, "batch", "seq", None, None)
            v = constrain(v, "batch", "seq", None, None)
        o = flash_attention(
            q,
            k,
            v,
            causal=True,
            window=cfg.sliding_window,
            q_block=cfg.q_block,
            kv_block=cfg.kv_block,
            unroll=cfg.unroll,
        )
        o = o.reshape(B, S, Hq * Dh)
    return x + o @ lp["wo"], (k, v)


def _ffn_block(x, lp, cfg: LMConfig):
    h = rms_norm(x, lp["mlp_norm"])
    if cfg.moe:
        shape = h.shape
        flat = h.reshape(-1, cfg.d_model)
        y, aux = moe_ffn(flat, lp["moe"], cfg.moe)
        return x + y.reshape(shape), aux
    y = swiglu(h, lp["mlp"]["w_gate"], lp["mlp"]["w_up"], lp["mlp"]["w_down"])
    return x + y, jnp.float32(0.0)


def forward(params: dict, tokens: jax.Array, cfg: LMConfig, remat: bool = True):
    """tokens [B, S] -> (logits [B, S, V] f32, aux loss)."""
    B, S = tokens.shape
    x = params["embed"][tokens]
    x = constrain(x, "batch", None, None)
    cos, sin = rope_angles(jnp.arange(S), cfg.d_head, cfg.rope_theta)

    def layer(carry, lp):
        x, aux = carry
        x, _ = _attn_block(x, lp, cfg, cos, sin, mode="train")
        x = constrain(x, "batch", None, None)
        x, a = _ffn_block(x, lp, cfg)
        x = constrain(x, "batch", None, None)
        return (x, aux + a), None

    # (§Perf note: selective remat — dots_with_no_batch_dims_saveable — was
    # tried and REFUTED: -7%% on the memory term but +4.6 GB/device resident
    # (9.9 -> 14.5 GB), breaking the 16 GB v5e fit. Full remat stays.)
    f = jax.checkpoint(layer) if remat else layer
    (x, aux), _ = maybe_scan(f, (x, jnp.float32(0.0)), params["layers"], unroll=cfg.unroll)
    x = rms_norm(x, params["final_norm"])
    logits = (x @ params["head"]).astype(jnp.float32)
    logits = constrain(logits, "batch", None, "model")
    return logits, aux / cfg.n_layers


def lm_loss(params: dict, tokens: jax.Array, labels: jax.Array, cfg: LMConfig,
            aux_weight: float = 0.01):
    # (§Perf note: a sequence-chunked head/loss — never materializing the
    # [B*S, V] f32 logits — was tried and REFUTED: +10% on the memory term
    # from the per-chunk head-matmul recompute, with resident memory already
    # within budget. The straightforward form stays.)
    logits, aux = forward(params, tokens, cfg)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = (lse - ll).mean()
    return nll + aux_weight * aux, {"nll": nll, "aux": aux}


def prefill(params: dict, tokens: jax.Array, cfg: LMConfig):
    """tokens [B, S] -> (logits of last position [B, V], KVCache)."""
    B, S = tokens.shape
    W, rolling = cache_window(cfg, S)
    x = params["embed"][tokens]
    x = constrain(x, "batch", None, None)
    cos, sin = rope_angles(jnp.arange(S), cfg.d_head, cfg.rope_theta)

    def layer(carry, lp):
        x = carry
        x, (k, v) = _attn_block(x, lp, cfg, cos, sin, mode="prefill")
        x, _ = _ffn_block(x, lp, cfg)
        x = constrain(x, "batch", None, None)
        # roll the last W positions into cache slots t % W
        kw, vw = k[:, -W:], v[:, -W:]
        pos_w = jnp.arange(S - W, S)
        slots = pos_w % W
        kc = jnp.zeros((B, W) + k.shape[2:], k.dtype).at[:, slots].set(kw)
        vc = jnp.zeros((B, W) + v.shape[2:], v.dtype).at[:, slots].set(vw)
        return x, (kc, vc)

    x, (kcs, vcs) = maybe_scan(jax.checkpoint(layer), x, params["layers"], unroll=cfg.unroll)
    x = rms_norm(x[:, -1], params["final_norm"])
    logits = (x @ params["head"]).astype(jnp.float32)
    return logits, KVCache(k=kcs, v=vcs)


def decode_step(params: dict, cache: KVCache, token: jax.Array, pos: jax.Array,
                cfg: LMConfig):
    """One decode step. token [B] int32, pos [B] absolute positions.
    Returns (logits [B, V] f32, updated cache).

    §Perf: the cache rides the scan CARRY and is updated with per-layer
    in-place scatters — XLA aliases the donated buffers, so HBM traffic is
    cache-READ + one-slot write instead of a full cache rewrite (the
    baseline passed the cache through scan xs/ys, which materializes a
    second full cache: ~2x the memory term on decode cells).
    """
    B = token.shape[0]
    L = cfg.n_layers
    x = params["embed"][token]
    x = constrain(x, "batch", None)
    cos, sin = rope_angles(pos, cfg.d_head, cfg.rope_theta)

    def layer(carry, xs):
        x, k_all, v_all = carry
        lp, li = xs
        x, (kc, vc) = _attn_block(
            x, lp, cfg, cos, sin, mode="decode", kc=k_all[li], vc=v_all[li],
            pos=pos
        )
        k_all = jax.lax.dynamic_update_index_in_dim(k_all, kc, li, 0)
        v_all = jax.lax.dynamic_update_index_in_dim(v_all, vc, li, 0)
        x, _ = _ffn_block(x, lp, cfg)
        return (x, k_all, v_all), None

    (x, kcs, vcs), _ = maybe_scan(
        layer, (x, cache.k, cache.v),
        (params["layers"], jnp.arange(L, dtype=jnp.int32)), unroll=cfg.unroll
    )
    x = rms_norm(x, params["final_norm"])
    logits = (x @ params["head"]).astype(jnp.float32)
    return logits, KVCache(k=kcs, v=vcs)
