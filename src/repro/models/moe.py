"""Mixture-of-Experts FFN: top-k routing, shard-local dispatch, expert
all-to-all, optional virtual-expert split.

§Perf evolution (measured in EXPERIMENTS.md):

* v0 (baseline): one global capacity buffer, token scatter/gather across
  the whole batch.  GSPMD cannot keep a data-dependent scatter across
  sharded dims local — it replicates: ~11 TB/chip/step of all-gather +
  all-reduce on mixtral train_4k.
* v1 (current): tokens are routed within **token blocks** aligned to the
  data shards (TB = pod*data = 32).  The one-hot position cumsum and both
  scatters are per-block (shard-local); the only cross-chip movement is
  the [E, TB, Cb, D] buffer's expert<->data transpose — the classic MoE
  all-to-all, which is the *minimal* traffic for expert parallelism.
* virtual experts: when E < |model| (mixtral: 8 < 16), each expert's d_ff
  is split ``virtual_split`` ways and stacked on the expert axis so
  weights stay resident (EPxTP); partial w_down products are pair-summed.

Per-block capacity Cb = ceil(cf * tokens_per_block * K / E): stricter than
global capacity under imbalance (standard trade-off; the router aux loss
pushes toward balance).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.configs.base import MoESpec
from repro.dist.sharding import axis_env, constrain

__all__ = ["moe_ffn", "router_aux_loss"]

_TOKEN_BLOCKS = 32  # pod * data


def moe_ffn(x: jax.Array, p: dict, spec: MoESpec) -> tuple[jax.Array, jax.Array]:
    """x: [T, D] flat tokens. p: router [D, E], w_gate/w_up [Ev, D, Fv],
    w_down [Ev, Fv, D]. Returns (out [T, D], aux router loss)."""
    T, D = x.shape
    E, K, vs = spec.n_experts, spec.top_k, spec.virtual_split
    TB = _TOKEN_BLOCKS if T % _TOKEN_BLOCKS == 0 else 1
    tp = T // TB
    Cb = max(1, int(spec.capacity_factor * tp * K / E))

    logits = x.astype(jnp.float32) @ p["router"].astype(jnp.float32)  # [T, E]
    topv, topi = jax.lax.top_k(logits, K)
    gates = jax.nn.softmax(topv, axis=-1)
    aux = router_aux_loss(logits, topi, E)

    A = tp * K
    assign_e = topi.reshape(TB, A)
    gate_b = gates.reshape(TB, A)
    keep_shape = assign_e.shape
    tok_b = jnp.repeat(jnp.arange(tp), K)  # [A] block-local token ids

    # block-local positions within each expert's capacity
    onehot = jax.nn.one_hot(assign_e, E, dtype=jnp.int32)  # [TB, A, E]
    pos = (jnp.cumsum(onehot, axis=1) * onehot).sum(-1) - 1  # [TB, A]
    keep = pos < Cb
    slot = jnp.where(keep, assign_e * Cb + pos, E * Cb)  # OOB -> dropped

    xb = x.reshape(TB, tp, D)

    def scatter_blocks(xb_l, slot_l):
        """Per-shard dispatch: plain local scatter (no GSPMD guessing)."""
        tbl = xb_l.shape[0]
        gathered = xb_l[:, tok_b]  # [tbl, A, D]
        rows = jnp.arange(tbl)[:, None]
        return jnp.zeros((tbl, E * Cb, D), xb_l.dtype).at[rows, slot_l].set(
            gathered, mode="drop"
        )

    env = axis_env()
    bx = env.resolve("batch") if env is not None else None
    if bx is not None and TB > 1:
        # shard_map pins the scatter to each data shard — v1 left it to
        # GSPMD, which replicated the [TB, E*Cb, D] buffer (measured ~2.5
        # TB/chip of all-gather on mixtral train; §Perf v2)
        buf = shard_map(
            scatter_blocks, mesh=env.mesh,
            in_specs=(P(bx, None, None), P(bx, None)),
            out_specs=P(bx, None, None), check_rep=False,
        )(xb, slot)
    else:
        buf = scatter_blocks(xb, slot)
    buf = constrain(buf, "batch", None, None)
    # expert <-> data transpose: THE all-to-all
    buf = buf.reshape(TB, E, Cb, D).transpose(1, 0, 2, 3)  # [E, TB, Cb, D]

    if vs > 1:
        buf_v = jnp.broadcast_to(buf[:, None], (E, vs, TB, Cb, D)).reshape(
            E * vs, TB, Cb, D
        )
        buf_v = constrain(buf_v, "expert", "batch", None, None)
        h = jax.nn.silu(jnp.einsum("ebcd,edf->ebcf", buf_v, p["w_gate"])) * jnp.einsum(
            "ebcd,edf->ebcf", buf_v, p["w_up"]
        )
        y_v = jnp.einsum("ebcf,efd->ebcd", h, p["w_down"])  # partial over F-split
        y_v = constrain(y_v, "expert", "batch", None, None)
        y = y_v.reshape(E, vs, TB, Cb, D).sum(axis=1)
    else:
        axes = ("expert", "batch", None, None) if spec.expert_parallel else (
            None, "batch", None, None)
        buf = constrain(buf, *axes)
        h = jax.nn.silu(jnp.einsum("ebcd,edf->ebcf", buf, p["w_gate"])) * jnp.einsum(
            "ebcd,edf->ebcf", buf, p["w_up"]
        )
        y = jnp.einsum("ebcf,efd->ebcd", h, p["w_down"])
        y = constrain(y, *axes)

    y = y.transpose(1, 0, 2, 3).reshape(TB, E * Cb, D)  # back: all-to-all
    y = constrain(y, "batch", None, None)

    def gather_blocks(y_l, slot_l, gk_l):
        tbl = y_l.shape[0]
        rows = jnp.arange(tbl)[:, None]
        contrib = y_l.at[rows, slot_l].get(mode="fill", fill_value=0.0)
        contrib = contrib * gk_l[..., None]
        return jnp.zeros((tbl, tp, D), y_l.dtype).at[rows, tok_b[None, :]].add(contrib)

    gk = (gate_b * keep).astype(y.dtype)
    if bx is not None and TB > 1:
        out = shard_map(
            gather_blocks, mesh=env.mesh,
            in_specs=(P(bx, None, None), P(bx, None), P(bx, None)),
            out_specs=P(bx, None, None), check_rep=False,
        )(y, slot, gk)
    else:
        out = gather_blocks(y, slot, gk)
    return out.reshape(T, D).astype(x.dtype), aux


def router_aux_loss(logits: jax.Array, topi: jax.Array, n_experts: int) -> jax.Array:
    """Switch-style load-balancing loss: E * <frac_tokens, frac_probs>."""
    probs = jax.nn.softmax(logits, axis=-1)
    frac_probs = probs.mean(axis=0)
    counts = jnp.zeros(n_experts, jnp.float32).at[topi.reshape(-1)].add(1.0)
    frac_tokens = counts / jnp.maximum(counts.sum(), 1.0)
    return n_experts * jnp.sum(frac_probs * frac_tokens)
