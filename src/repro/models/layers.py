"""Shared neural building blocks (pure-jnp, scan-friendly)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["rms_norm", "rope_angles", "apply_rope", "swiglu", "dense_init", "Initializer",
           "maybe_scan"]


def maybe_scan(f, init, xs, unroll: bool = False):
    """lax.scan, or a python unroll producing straight-line HLO.

    The unrolled form exists for the roofline lowering: XLA's
    ``cost_analysis`` counts a while-loop body ONCE regardless of trip
    count, so scanned programs under-report FLOPs/bytes by the trip count.
    Unrolled lowerings pay that cost in HLO size instead (coarse attention
    blocks keep it bounded) and are never executed — only analysed.
    """
    if not unroll:
        return jax.lax.scan(f, init, xs)
    n = jax.tree.leaves(xs)[0].shape[0]
    carry = init
    ys = []
    for i in range(n):
        carry, y = f(carry, jax.tree.map(lambda x: x[i], xs))
        ys.append(y)
    if ys and ys[0] is not None:
        ys = jax.tree.map(lambda *zs: jnp.stack(zs), *ys)
    else:
        ys = None
    return carry, ys


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * scale.astype(jnp.float32)).astype(dt)


def rope_angles(positions: jax.Array, d_head: int, theta: float) -> tuple[jax.Array, jax.Array]:
    """cos/sin tables for given absolute positions; shapes [..., d_head/2]."""
    half = d_head // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freq  # [..., half]
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """Rotate pairs (split-half convention). x: [..., S, H, D]; cos/sin
    broadcastable [..., S, 1, D/2]."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1)
    return out.astype(x.dtype)


def swiglu(x: jax.Array, w_gate: jax.Array, w_up: jax.Array, w_down: jax.Array) -> jax.Array:
    h = jax.nn.silu(x @ w_gate) * (x @ w_up)
    return h @ w_down


class Initializer:
    """Deterministic fan-in-scaled normal init, one fold per param path."""

    def __init__(self, key: jax.Array):
        self.key = key
        self._i = 0

    def __call__(self, shape, fan_in: int | None = None, dtype=jnp.float32):
        self._i += 1
        k = jax.random.fold_in(self.key, self._i)
        fi = fan_in if fan_in is not None else (shape[-2] if len(shape) >= 2 else shape[-1])
        return (jax.random.normal(k, shape, dtype=jnp.float32) / jnp.sqrt(fi)).astype(dtype)


def dense_init(init: Initializer, d_in: int, d_out: int, n_layers: int | None = None, dtype=jnp.float32):
    shape = (n_layers, d_in, d_out) if n_layers else (d_in, d_out)
    return init(shape, fan_in=d_in, dtype=dtype)
