"""AdamW + train-state (pure pytrees, donate-friendly, shardable).

Master params are kept in the model's compute dtype; Adam moments in f32.
The update math runs in f32 regardless of param dtype.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["AdamConfig", "TrainState", "init_train_state", "adamw_update", "global_norm",
           "cosine_lr"]


@dataclasses.dataclass(frozen=True)
class AdamConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000


@partial(
    jax.tree_util.register_dataclass,
    data_fields=["params", "m", "v", "step", "err"],
    meta_fields=[],
)
@dataclasses.dataclass(frozen=True)
class TrainState:
    params: Any
    m: Any
    v: Any
    step: jax.Array
    err: Any = None  # gradient-compression error-feedback buffers (optional)


def init_train_state(params: Any, with_error_feedback: bool = False) -> TrainState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return TrainState(
        params=params,
        m=jax.tree.map(zeros, params),
        v=jax.tree.map(zeros, params),
        step=jnp.zeros((), jnp.int32),
        err=jax.tree.map(zeros, params) if with_error_feedback else None,
    )


def global_norm(tree: Any) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree))
    )


def cosine_lr(cfg: AdamConfig, step: jax.Array) -> jax.Array:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip(
        (step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0,
        1.0,
    )
    return cfg.lr * warm * 0.5 * (1.0 + jnp.cos(jnp.pi * t))


def adamw_update(state: TrainState, grads: Any, cfg: AdamConfig) -> tuple[TrainState, dict]:
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    step = state.step + 1
    lr = cosine_lr(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mh = m / bc1
        vh = v / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(state.params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return (
        dataclasses.replace(state, params=new_p, m=new_m, v=new_v, step=step),
        {"grad_norm": gnorm, "lr": lr},
    )
