from .optimizer import AdamConfig, TrainState, adamw_update, cosine_lr, global_norm, init_train_state
from .train_step import make_train_step

__all__ = ["AdamConfig", "TrainState", "adamw_update", "cosine_lr", "global_norm",
           "init_train_state", "make_train_step"]
