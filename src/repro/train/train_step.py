"""Generic train-step factory: grad (with optional microbatch accumulation
via lax.scan — lets XLA overlap microbatch k's reduce-scatter with k+1's
compute), optional int8 error-feedback gradient compression, AdamW update.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.dist.compression import ef_compress_tree
from repro.train.optimizer import AdamConfig, TrainState, adamw_update

__all__ = ["make_train_step"]


def make_train_step(
    loss_fn: Callable[[Any, Any], tuple[jax.Array, dict]],
    adam: AdamConfig,
    *,
    microbatches: int = 1,
    compress: bool = False,
):
    """loss_fn(params, batch) -> (scalar loss, metrics dict).

    Returns train_step(state, batch) -> (state', metrics).  With
    ``microbatches > 1`` the batch's leading dims are split and gradients
    accumulated in f32 through a lax.scan.
    """

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def single(params, batch):
        (loss, metrics), grads = grad_fn(params, batch)
        return loss, metrics, grads

    def accumulate(params, batch):
        def split(x):
            b = x.shape[0]
            assert b % microbatches == 0, (b, microbatches)
            return x.reshape(microbatches, b // microbatches, *x.shape[1:])

        mb = jax.tree.map(split, batch)
        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

        def body(carry, mbatch):
            gsum, lsum = carry
            (loss, metrics), grads = grad_fn(params, mbatch)
            gsum = jax.tree.map(
                lambda a, g: a + g.astype(jnp.float32) / microbatches, gsum, grads
            )
            return (gsum, lsum + loss / microbatches), metrics

        (grads, loss), metrics = jax.lax.scan(body, (g0, 0.0), mb)
        metrics = jax.tree.map(lambda x: x[-1], metrics)
        return loss, metrics, grads

    def train_step(state: TrainState, batch) -> tuple[TrainState, dict]:
        fn = accumulate if microbatches > 1 else single
        loss, metrics, grads = fn(state.params, batch)
        if compress:
            grads, new_err = ef_compress_tree(grads, state.err)
            state = dataclasses.replace(state, err=new_err)
        state, opt_metrics = adamw_update(state, grads, adam)
        return state, {"loss": loss, **metrics, **opt_metrics}

    return train_step
