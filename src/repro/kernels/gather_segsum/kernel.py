"""Pallas TPU block-sparse gather+segment-sum (SpMM): the message-passing /
embedding-bag / peel-round primitive.

TPU adaptation (DESIGN.md §2): element-wise scatter-add is hostile to the
MXU/VPU, so edges are pre-bucketed into dense 128x128 adjacency tiles
(block-CSR).  The kernel walks tiles sorted by destination block; a
*scalar-prefetch* index vector selects the source x-block and destination
out-block per step (block-level gather/scatter — the Mosaic-friendly form
of sparse indexing), and each step is one MXU matmul:

    out[tile_dst[t]] += tiles[t][128, 128] @ x[tile_src[t]]   # x-block [128, F]

Output-block revisiting across consecutive grid steps keeps the
accumulator in VMEM; a first-visit flag zero-initializes it.  Power-law
graphs give sparse tiles — the preprocessing (ops.py) reports tile
occupancy, and graph reordering (degree sort) is the documented
mitigation.  Validated in interpret mode against ``ref.py``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# renamed TPUCompilerParams -> CompilerParams across jax releases
_COMPILER_PARAMS = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

__all__ = ["block_spmm"]


def _kernel(src_blk_ref, dst_blk_ref, first_ref, tiles_ref, x_ref, o_ref):
    t = pl.program_id(1)  # grid = (nf, T): tiles are the inner axis

    @pl.when(first_ref[t] == 1)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    a = tiles_ref[0].astype(jnp.float32)  # [bs, bs]
    x = x_ref[0].astype(jnp.float32)  # [bs, f_tile]
    o_ref[...] += jax.lax.dot_general(
        a, x, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )[None].astype(o_ref.dtype)


def block_spmm(
    tiles: jax.Array,  # [T, bs, bs] dense tile values, A[dst_local, src_local]
    tile_src: jax.Array,  # [T] int32 source block ids
    tile_dst: jax.Array,  # [T] int32 destination block ids (sorted, gapless)
    first_visit: jax.Array,  # [T] int32, 1 where tile_dst changes
    x: jax.Array,  # [n_src_blocks * bs, F]
    n_out_blocks: int,
    *,
    f_tile: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """out[tile_dst[t]] += tiles[t] @ x[tile_src[t]] over all tiles.

    Requires tiles sorted by ``tile_dst`` with every output block visited
    at least once (ops.py inserts zero tiles for empty blocks so the
    zero-init fires everywhere).
    """
    T, bs, _ = tiles.shape
    F = x.shape[1]
    nf = -(-F // f_tile)
    pad = nf * f_tile - F
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad)))
    xb = x.reshape(-1, bs, nf * f_tile)

    out = pl.pallas_call(
        _kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,  # (tile_src, tile_dst, first_visit)
            grid=(nf, T),
            in_specs=[
                pl.BlockSpec((1, bs, bs), lambda f, t, src, dst, first: (t, 0, 0)),
                pl.BlockSpec(
                    (1, bs, f_tile), lambda f, t, src, dst, first: (src[t], 0, f)
                ),
            ],
            out_specs=pl.BlockSpec(
                (1, bs, f_tile), lambda f, t, src, dst, first: (dst[t], 0, f)
            ),
        ),
        out_shape=jax.ShapeDtypeStruct((n_out_blocks, bs, nf * f_tile), jnp.float32),
        compiler_params=_COMPILER_PARAMS(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(tile_src, tile_dst, first_visit, tiles, xb)
    out = out.reshape(n_out_blocks * bs, nf * f_tile)
    return out[:, :F]
