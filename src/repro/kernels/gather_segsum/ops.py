"""Preprocessing + jit'd wrapper for the block-sparse SpMM kernel.

``build_tiles`` buckets COO edges into dense 128x128 tiles (host-side, part
of the data pipeline — graphs are tiled once and updated incrementally);
``gather_segsum`` runs the Pallas kernel (TPU) / interpret (validation) /
segment-sum reference (CPU production).
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from .kernel import block_spmm
from .ref import spmm_ref


@dataclass
class BlockTiles:
    tiles: np.ndarray  # [T, bs, bs] f32
    tile_src: np.ndarray  # [T] i32
    tile_dst: np.ndarray  # [T] i32 (sorted)
    first_visit: np.ndarray  # [T] i32
    n_out_blocks: int
    n_src_blocks: int
    block_size: int
    occupancy: float  # nnz / (T * bs * bs) — tile density diagnostic


def build_tiles(src, dst, val, n_dst, n_src, block_size: int = 128) -> BlockTiles:
    src = np.asarray(src, np.int64)
    dst = np.asarray(dst, np.int64)
    val = (np.ones(src.shape[0], np.float32) if val is None
           else np.asarray(val, np.float32))
    bs = block_size
    n_db = -(-n_dst // bs)
    n_sb = -(-n_src // bs)
    db, sb = dst // bs, src // bs
    key = db * n_sb + sb
    order = np.argsort(key, kind="stable")
    src, dst, val, key, db, sb = (a[order] for a in (src, dst, val, key, db, sb))
    uniq, start = np.unique(key, return_index=True)
    # ensure every dst block appears (zero tile) so init fires
    present = np.unique(uniq // n_sb)
    missing = np.setdiff1d(np.arange(n_db), present)
    T = uniq.shape[0] + missing.shape[0]
    tiles = np.zeros((T, bs, bs), np.float32)
    t_src = np.zeros(T, np.int32)
    t_dst = np.zeros(T, np.int32)
    ends = np.append(start[1:], key.shape[0])
    for i, (k, s, e) in enumerate(zip(uniq, start, ends)):
        t_dst[i] = k // n_sb
        t_src[i] = k % n_sb
        np.add.at(tiles[i], (dst[s:e] % bs, src[s:e] % bs), val[s:e])
    for j, mb in enumerate(missing):
        t_dst[uniq.shape[0] + j] = mb
        t_src[uniq.shape[0] + j] = 0
    reorder = np.argsort(t_dst, kind="stable")
    tiles, t_src, t_dst = tiles[reorder], t_src[reorder], t_dst[reorder]
    first = np.zeros(T, np.int32)
    first[0] = 1
    first[1:] = (t_dst[1:] != t_dst[:-1]).astype(np.int32)
    occ = float(val.shape[0]) / float(T * bs * bs)
    return BlockTiles(tiles, t_src, t_dst, first, n_db, n_sb, bs, occ)


def gather_segsum(bt: BlockTiles, x: jax.Array, n_out: int, *,
                  force: str | None = None) -> jax.Array:
    mode = force or ("pallas" if jax.default_backend() == "tpu" else "interpret")
    out = block_spmm(
        jnp.asarray(bt.tiles), jnp.asarray(bt.tile_src), jnp.asarray(bt.tile_dst),
        jnp.asarray(bt.first_visit),
        jnp.pad(x, ((0, bt.n_src_blocks * bt.block_size - x.shape[0]), (0, 0))),
        bt.n_out_blocks,
        interpret=(mode == "interpret"),
    )
    return out[:n_out]
