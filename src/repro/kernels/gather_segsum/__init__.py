from .ops import BlockTiles, build_tiles, gather_segsum
from .ref import spmm_ref

__all__ = ["BlockTiles", "build_tiles", "gather_segsum", "spmm_ref"]
