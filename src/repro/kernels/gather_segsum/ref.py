"""Pure-jnp oracle: COO gather + segment-sum (identical contract to
repro.graphstore.segment_ops.gather_scatter_sum)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def spmm_ref(src, dst, val, x, n_out):
    """out[d] = sum_{e: dst_e = d} val_e * x[src_e].  x: [N, F]."""
    msgs = x[src] * val[:, None]
    return jax.ops.segment_sum(msgs, dst, num_segments=n_out)
