"""Pallas TPU flash-attention forward (blocked online softmax, GQA).

Grid: (B*Hq, nQ, nK); the kv axis is the innermost ("arbitrary") dimension
so the (m, l, acc) online-softmax state lives in VMEM scratch across kv
steps.  BlockSpecs tile q/k/v/o into VMEM:

  q: [1, Bq, D]   k/v: [1, Bk, D]   o: [1, Bq, D]

GQA is handled in the k/v index maps (kv head = q head // G) — no
repeat-materialization of k/v in HBM.  Causal / sliding-window masking is
applied per tile; fully-masked tiles skip the matmuls via ``pl.when``.

Targets TPU (MXU-aligned Bq/Bk/D multiples of 128); validated on CPU in
interpret mode against ``ref.py``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# renamed TPUCompilerParams -> CompilerParams across jax releases
_COMPILER_PARAMS = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

__all__ = ["flash_attention_fwd"]

_NEG = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale, causal, window, n_kv, bq, bk, seq_q, seq_kv):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    ok = k_pos < seq_kv
    if causal:
        ok &= k_pos <= q_pos
    if window is not None:
        ok &= k_pos > q_pos - window

    # a tile is live unless every position is masked; for causal grids this
    # skips the strictly-upper-triangular tiles (real FLOP savings on TPU)
    live = jnp.logical_not(causal) | (ki * bk <= qi * bq + bq - 1)
    if window is not None:
        live &= (qi * bq - window) < ((ki + 1) * bk - 1) + bq

    @pl.when(live)
    def _step():
        q = q_ref[0].astype(jnp.float32)  # [bq, d]
        k = k_ref[0].astype(jnp.float32)  # [bk, d]
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale
        s = jnp.where(ok, s, _NEG)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new

    @pl.when(ki == n_kv - 1)
    def _finish():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[:, None]).astype(
            o_ref.dtype
        )


def flash_attention_fwd(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    block_q: int = 256,
    block_k: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """q: [B, Hq, Sq, D]; k/v: [B, Hkv, Skv, D] -> [B, Hq, Sq, D]."""
    B, Hq, Sq, D = q.shape
    Hkv, Skv = k.shape[1], k.shape[2]
    assert Hq % Hkv == 0
    G = Hq // Hkv
    bq, bk = min(block_q, Sq), min(block_k, Skv)
    nq, nk = -(-Sq // bq), -(-Skv // bk)
    pq, pk = nq * bq - Sq, nk * bk - Skv
    if pq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pq), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pk), (0, 0)))
    qf = q.reshape(B * Hq, nq * bq, D)
    kf = k.reshape(B * Hkv, nk * bk, D)
    vf = v.reshape(B * Hkv, nk * bk, D)

    def kv_map(bh, qi, ki):
        return ((bh // Hq) * Hkv + (bh % Hq) // G, ki, 0)

    out = pl.pallas_call(
        functools.partial(
            _kernel,
            scale=1.0 / (D ** 0.5),
            causal=causal,
            window=window,
            n_kv=nk,
            bq=bq,
            bk=bk,
            seq_q=Sq,
            seq_kv=Skv,
        ),
        grid=(B * Hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, bk, D), kv_map),
            pl.BlockSpec((1, bk, D), kv_map),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B * Hq, nq * bq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
        compiler_params=_COMPILER_PARAMS(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, Hq, nq * bq, D)[:, :, :Sq]
