"""Jit'd public wrapper for the Pallas flash-attention kernel.

On TPU runtimes the Pallas path is used; elsewhere (this CPU container)
``interpret=True`` executes the kernel body in Python for validation, and
production CPU falls back to the reference.
"""

from __future__ import annotations

from functools import partial

import jax

from .kernel import flash_attention_fwd
from .ref import attention_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("causal", "window", "block_q", "block_k", "force"))
def flash_attention(q, k, v, *, causal=True, window=None, block_q=256, block_k=256,
                    force: str | None = None):
    """force: None (auto), 'pallas', 'interpret', 'ref'."""
    mode = force or ("pallas" if _on_tpu() else "ref")
    if mode == "pallas":
        return flash_attention_fwd(q, k, v, causal=causal, window=window,
                                   block_q=block_q, block_k=block_k)
    if mode == "interpret":
        return flash_attention_fwd(q, k, v, causal=causal, window=window,
                                   block_q=block_q, block_k=block_k, interpret=True)
    return attention_ref(q, k, v, causal=causal, window=window)
