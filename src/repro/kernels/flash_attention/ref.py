"""Pure-jnp oracle for the flash-attention kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp

_NEG = -1e30


def attention_ref(q, k, v, *, causal=True, window=None):
    """q: [B, Hq, Sq, D]; k/v: [B, Hkv, Skv, D] -> [B, Hq, Sq, D]."""
    B, Hq, Sq, D = q.shape
    Hkv, Skv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    qf = q.reshape(B, Hkv, G, Sq, D).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qf, kf) / (D ** 0.5)
    qpos = jnp.arange(Sq)[:, None]
    kpos = jnp.arange(Skv)[None, :]
    ok = jnp.ones((Sq, Skv), bool)
    if causal:
        ok &= kpos <= qpos
    if window is not None:
        ok &= kpos > qpos - window
    s = jnp.where(ok[None, None, None], s, _NEG)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bhkd->bhgqd", p, vf)
    return o.reshape(B, Hq, Sq, D).astype(q.dtype)
