"""Pallas TPU kernels for the perf-critical compute layers, each with an
``ops.py`` jit wrapper and a ``ref.py`` pure-jnp oracle:

* ``flash_attention`` — blocked online-softmax GQA attention (LM hot path)
* ``gather_segsum``   — block-sparse SpMM via scalar-prefetch block gather
                        (GNN message passing / embedding bag / peel SpMV)
* ``peel_round``      — fused elementwise half of a bulk-peeling round
                        (the paper's maintenance hot path)

This container is CPU-only: kernels target TPU (pl.pallas_call + BlockSpec
VMEM tiling) and are validated in interpret mode; ops.py wrappers fall
back to references off-TPU.
"""
