from .ops import peel_round
from .ref import peel_round_ref

__all__ = ["peel_round", "peel_round_ref"]
