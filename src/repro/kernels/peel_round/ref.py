"""Pure-jnp oracle for the fused peel-round update."""

from __future__ import annotations

import jax.numpy as jnp


def peel_round_ref(w, a, active, level, dw, thresh, round_):
    peeled = active & (w <= thresh)
    w2 = w - dw
    active2 = active & ~peeled
    level2 = jnp.where(peeled, round_, level)
    pf = peeled.astype(jnp.float32)
    partials = jnp.stack([
        jnp.sum(pf * a), jnp.sum(pf * w), jnp.sum(pf)
    ])
    return w2, active2, level2, peeled, partials
