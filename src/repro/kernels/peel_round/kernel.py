"""Pallas TPU fused peel-round kernel: the elementwise half of one bulk-
peeling round (threshold compare + weight/mask update), vertex-tiled.

One bulk round is  (1) peeled = active & (w <= thresh)  and  (2) the
SpMV  dw[v] = sum_{(u,v) alive, u peeled} c_uv  (which IS
``gather_segsum`` with F=1 and x = peeled-indicator).  This kernel fuses
step (1) with the state update of step (2)'s output — one VMEM pass over
the vertex arrays instead of four XLA elementwise kernels:

    peeled     = active & (w <= thresh)
    w'         = w - dw
    active'    = active & ~peeled
    level'     = peeled ? round : level
    partials   = [sum(peeled a), sum(peeled w), n_peeled]   (for f/n update)

Grid: vertex tiles of 8*128 lanes; partial reductions land in a small
output accumulated on the host side of the call (one scalar triple per
tile).  Validated in interpret mode against ``ref.py``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["peel_round_update"]


def _kernel(w_ref, a_ref, active_ref, level_ref, dw_ref, thresh_ref, round_ref,
            w_out, active_out, level_out, peeled_out, partial_out):
    w = w_ref[...]
    active = active_ref[...]
    thresh = thresh_ref[0]
    peeled = jnp.logical_and(active, w <= thresh)
    pf = peeled.astype(jnp.float32)
    w_out[...] = w - dw_ref[...]
    active_out[...] = jnp.logical_and(active, jnp.logical_not(peeled))
    level_out[...] = jnp.where(peeled, round_ref[0], level_ref[...])
    peeled_out[...] = peeled
    partial_out[0, 0] = jnp.sum(pf * a_ref[...])
    partial_out[0, 1] = jnp.sum(pf * w)
    partial_out[0, 2] = jnp.sum(pf)


def peel_round_update(
    w: jax.Array,  # [V] f32 peel weights
    a: jax.Array,  # [V] f32 vertex suspiciousness
    active: jax.Array,  # [V] bool
    level: jax.Array,  # [V] i32
    dw: jax.Array,  # [V] f32 (from the SpMV over peeled frontier)
    thresh: jax.Array,  # scalar f32
    round_: jax.Array,  # scalar i32
    *,
    block: int = 8 * 128 * 8,
    interpret: bool = False,
):
    """Returns (w', active', level', peeled, partials [n_tiles, 3])."""
    V = w.shape[0]
    nb = -(-V // block)
    pad = nb * block - V
    if pad:
        w = jnp.pad(w, (0, pad))
        a = jnp.pad(a, (0, pad))
        active = jnp.pad(active, (0, pad))
        level = jnp.pad(level, (0, pad))
        dw = jnp.pad(dw, (0, pad))
    thresh = jnp.reshape(thresh.astype(jnp.float32), (1,))
    round_ = jnp.reshape(round_.astype(jnp.int32), (1,))

    vec = lambda: pl.BlockSpec((block,), lambda i: (i,))
    scl = lambda: pl.BlockSpec((1,), lambda i: (0,))
    outs = pl.pallas_call(
        _kernel,
        grid=(nb,),
        in_specs=[vec(), vec(), vec(), vec(), vec(), scl(), scl()],
        out_specs=[
            vec(), vec(), vec(), vec(),
            pl.BlockSpec((1, 3), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nb * block,), jnp.float32),
            jax.ShapeDtypeStruct((nb * block,), jnp.bool_),
            jax.ShapeDtypeStruct((nb * block,), jnp.int32),
            jax.ShapeDtypeStruct((nb * block,), jnp.bool_),
            jax.ShapeDtypeStruct((nb, 3), jnp.float32),
        ],
        interpret=interpret,
    )(w, a, active, level, dw, thresh, round_)
    w2, active2, level2, peeled, partials = outs
    return w2[:V], active2[:V], level2[:V], peeled[:V], partials
