"""Jit'd wrapper for the fused peel-round kernel."""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .kernel import peel_round_update
from .ref import peel_round_ref


@partial(jax.jit, static_argnames=("force",))
def peel_round(w, a, active, level, dw, thresh, round_, force: str | None = None):
    mode = force or ("pallas" if jax.default_backend() == "tpu" else "ref")
    if mode == "ref":
        return peel_round_ref(w, a, active, level, dw, thresh, round_)
    out = peel_round_update(
        w, a, active, level, dw, jnp.asarray(thresh), jnp.asarray(round_),
        interpret=(mode == "interpret"),
    )
    w2, active2, level2, peeled, partials = out
    return w2, active2, level2, peeled, partials.sum(axis=0)
