"""Device-plane (JAX/TPU) peeling algorithms.

Two formulations of the paper's Algorithm 1:

* :func:`exact_peel` — **paper-faithful sequential peel**: one vertex per
  step (masked argmin over a dense weight vector + scatter-subtract of its
  incident suspiciousness).  Bit-exact against the host oracle under the
  (weight, id) tie-break; O(V) steps of O(E) work.  This is the faithful
  baseline recorded in EXPERIMENTS.md §Perf.

* :func:`bulk_peel` — **TPU-native bulk peeling** (beyond-paper
  optimization; Bahmani et al., VLDB'12 — the paper's own reference [2]):
  each round peels *every* active vertex with
  ``w_u <= 2(1+eps) * g(S)``, converging in O(log_{1+eps} V) rounds of
  pure streaming segment-sums over the edge-partitioned COO graph.  It
  carries a ``2(1+eps)``-approximation guarantee and is the form that
  scales to multi-pod meshes: per-round work is two masked
  ``segment_sum`` passes (HBM-bandwidth-bound) + an ``all_reduce`` of
  vertex deltas when edges are sharded.

Both return a *peel level* per vertex (sequential: the step index;
bulk: the round index) from which the detected community is the suffix
``level >= best_level``.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.graphstore.structs import DeviceGraph

__all__ = ["PeelResultDevice", "exact_peel", "bulk_peel", "bulk_peel_warm"]

_INF = jnp.float32(jnp.inf)


class PeelResultDevice(NamedTuple):
    """Result of a device peel.

    ``level[u]``: step/round at which u was peeled (int32; padding = -1).
    ``best_level``: community = vertices with ``level >= best_level``.
    ``best_g``: density of the detected community.
    ``n_rounds``: rounds (bulk) or steps (exact) executed.
    ``order``: exact peel only — the peeling sequence (vertex ids), else
      zeros. ``delta``: peel-time weights aligned with ``order``/vertex id.
    """

    level: jax.Array
    best_level: jax.Array
    best_g: jax.Array
    n_rounds: jax.Array
    order: jax.Array
    delta: jax.Array

    def community_mask(self) -> jax.Array:
        return self.level >= self.best_level


# ---------------------------------------------------------------------------
# exact sequential peel (paper-faithful)
# ---------------------------------------------------------------------------


def exact_peel(g: DeviceGraph) -> PeelResultDevice:
    """Algorithm 1, one vertex per step, deterministic (w, id) tie-break."""
    V, E = g.n_capacity, g.e_capacity
    cm = jnp.where(g.edge_mask, g.c, 0.0)
    w0 = g.peel_weights()
    f0 = g.f_total()
    n0 = jnp.sum(g.vertex_mask)

    def body(i, carry):
        w, active, f, n_act, order, delta, level, best_g, best_i = carry
        key = jnp.where(active, w, _INF)
        u = jnp.argmin(key)  # ties -> lowest id (matches host oracle)
        wu = key[u]
        # density of the set *before* this peel
        g_cur = jnp.where(n_act > 0, f / jnp.maximum(n_act, 1), -_INF)
        improved = g_cur > best_g
        best_g = jnp.where(improved, g_cur, best_g)
        best_i = jnp.where(improved, i, best_i)

        live = jnp.where(active, 1.0, 0.0)
        touch_s = (g.src == u) & g.edge_mask
        touch_d = (g.dst == u) & g.edge_mask
        dw = jax.ops.segment_sum(
            jnp.where(touch_s, cm, 0.0) * live[g.dst], g.dst, num_segments=V
        ) + jax.ops.segment_sum(
            jnp.where(touch_d, cm, 0.0) * live[g.src], g.src, num_segments=V
        )
        peel_now = n_act > 0
        w = jnp.where(peel_now, w - dw, w)
        active = active & ~((jnp.arange(V) == u) & peel_now)
        order = order.at[i].set(jnp.where(peel_now, u, -1))
        delta = delta.at[i].set(jnp.where(peel_now, wu, 0.0))
        level = level.at[u].set(jnp.where(peel_now, i, level[u]))
        f = jnp.where(peel_now, f - wu, f)
        n_act = n_act - jnp.where(peel_now, 1, 0)
        return (w, active, f, n_act, order, delta, level, best_g, best_i)

    init = (
        w0,
        g.vertex_mask,
        f0,
        n0,
        jnp.full(V, -1, jnp.int32),
        jnp.zeros(V, jnp.float32),
        jnp.full(V, -1, jnp.int32),
        -_INF,
        jnp.int32(0),
    )
    w, active, f, n_act, order, delta, level, best_g, best_i = jax.lax.fori_loop(
        0, V, body, init
    )
    return PeelResultDevice(
        level=level,
        best_level=best_i,
        best_g=best_g,
        n_rounds=n0,
        order=order,
        delta=delta,
    )


# ---------------------------------------------------------------------------
# bulk parallel peel (TPU-native; 2(1+eps)-approximation)
# ---------------------------------------------------------------------------


class _BulkState(NamedTuple):
    w: jax.Array
    active: jax.Array
    edge_alive: jax.Array
    f: jax.Array
    n_act: jax.Array
    level: jax.Array
    best_g: jax.Array
    best_level: jax.Array
    round_: jax.Array


def _bulk_round(g: DeviceGraph, eps: float, s: _BulkState) -> _BulkState:
    """One bulk-peeling round.

    (§Perf note: deriving edge liveness on the fly instead of carrying the
    [E] bool state was tried and REFUTED — two extra [E]-sized gathers +
    mask ops cost more HBM traffic than the stored array saves.)
    """
    V = g.n_capacity
    g_cur = s.f / jnp.maximum(s.n_act, 1).astype(jnp.float32)
    improved = (g_cur > s.best_g) & (s.n_act > 0)
    best_g = jnp.where(improved, g_cur, s.best_g)
    best_level = jnp.where(improved, s.round_, s.best_level)

    thresh = 2.0 * (1.0 + eps) * g_cur
    peel = s.active & (s.w <= thresh)
    # progress guarantee: avg_u w_u <= 2 g(S), so the min-weight vertex
    # always peels *in exact arithmetic*.  Under f32 the running f can
    # drift slightly negative on a nearly-drained set, pushing the
    # threshold below every remaining weight and stalling the while_loop;
    # force-peel the min-weight vertices then (a no-op whenever the
    # threshold test already fired, hence invisible on integer weights).
    wmin = jnp.min(jnp.where(s.active, s.w, _INF))
    peel = jnp.where(jnp.any(peel), peel, s.active & (s.w <= wmin))
    e_ps = peel[g.src]
    e_pd = peel[g.dst]
    cm = jnp.where(s.edge_alive, g.c, 0.0)
    # f loses peeled vertex weight + every edge with >= 1 peeled endpoint
    f = (
        s.f
        - jnp.sum(jnp.where(peel, g.a, 0.0))
        - jnp.sum(jnp.where(e_ps | e_pd, cm, 0.0))
    )
    # survivors lose suspiciousness of edges to peeled endpoints
    dw = jax.ops.segment_sum(
        jnp.where(e_ps & ~e_pd, cm, 0.0), g.dst, num_segments=V
    ) + jax.ops.segment_sum(jnp.where(e_pd & ~e_ps, cm, 0.0), g.src, num_segments=V)
    w = s.w - dw
    return _BulkState(
        w=w,
        active=s.active & ~peel,
        edge_alive=s.edge_alive & ~(e_ps | e_pd),
        f=f,
        n_act=s.n_act - jnp.sum(peel),
        level=jnp.where(peel, s.round_, s.level),
        best_g=best_g,
        best_level=best_level,
        round_=s.round_ + 1,
    )


@partial(jax.jit, static_argnames=("eps", "max_rounds", "unroll"))
def bulk_peel(
    g: DeviceGraph, eps: float = 0.1, max_rounds: int = 0, unroll: bool = False
) -> PeelResultDevice:
    """Threshold bulk peeling; guarantees ``g_best >= g* / (2(1+eps))``.

    ``max_rounds = 0`` runs to completion (while_loop); a positive value
    bounds the round count (useful for fixed-cost serving ticks).
    ``unroll`` python-unrolls max_rounds rounds (roofline lowering).
    """
    w0 = g.peel_weights()
    init = _BulkState(
        w=w0,
        active=g.vertex_mask,
        edge_alive=g.edge_mask,
        f=g.f_total(),
        n_act=jnp.sum(g.vertex_mask),
        level=jnp.full(g.n_capacity, -1, jnp.int32),
        best_g=-_INF,
        best_level=jnp.int32(0),
        round_=jnp.int32(0),
    )

    state = _run_rounds(partial(_bulk_round, g, eps), init, max_rounds, unroll)
    return PeelResultDevice(
        level=state.level,
        best_level=state.best_level,
        best_g=state.best_g,
        n_rounds=state.round_,
        order=jnp.zeros(g.n_capacity, jnp.int32),
        delta=state.w,
    )


def _run_rounds(round_fn, init, max_rounds: int, unroll: bool = False):
    if unroll and max_rounds:
        s = init
        for _ in range(max_rounds):
            s = round_fn(s)
        return s
    if max_rounds and max_rounds > 0:
        return jax.lax.fori_loop(0, max_rounds, lambda i, s: round_fn(s), init)
    return jax.lax.while_loop(lambda s: s.n_act > 0, round_fn, init)


def bulk_peel_warm(
    g: DeviceGraph,
    keep: jax.Array,
    prior_best_g: jax.Array,
    eps: float = 0.1,
    max_rounds: int = 0,
    unroll: bool = False,
) -> PeelResultDevice:
    """Bulk peel restricted to ``keep`` vertices (warm start).

    Used by the incremental suffix re-peel: vertices outside ``keep`` are
    treated as already peeled; weights, f and n are recovered w.r.t. the
    restricted set, so every round's threshold is valid on the current set
    and the 2(1+eps) guarantee is preserved (DESIGN.md §2).  ``prior_best_g``
    seeds the best-density tracker so the maintained best never regresses.
    """
    V = g.n_capacity
    live = keep & g.vertex_mask
    both = live[g.src] & live[g.dst] & g.edge_mask
    cm = jnp.where(both, g.c, 0.0)
    w0 = jnp.where(live, g.a, 0.0)
    w0 = w0 + jax.ops.segment_sum(cm, g.src, num_segments=V)
    w0 = w0 + jax.ops.segment_sum(cm, g.dst, num_segments=V)
    f0 = jnp.sum(jnp.where(live, g.a, 0.0)) + jnp.sum(cm)

    init = _BulkState(
        w=w0,
        active=live,
        edge_alive=both,
        f=f0,
        n_act=jnp.sum(live),
        level=jnp.full(V, -1, jnp.int32),
        best_g=prior_best_g.astype(jnp.float32),
        best_level=jnp.int32(0),
        round_=jnp.int32(0),
    )
    state = _run_rounds(partial(_bulk_round, g, eps), init, max_rounds, unroll)
    return PeelResultDevice(
        level=state.level,
        best_level=state.best_level,
        best_g=state.best_g,
        n_rounds=state.round_,
        order=jnp.zeros(V, jnp.int32),
        delta=state.w,
    )
