"""Device-plane (JAX/TPU) peeling algorithms.

Two formulations of the paper's Algorithm 1:

* :func:`exact_peel` — **paper-faithful sequential peel**: one vertex per
  step (masked argmin over a dense weight vector + scatter-subtract of its
  incident suspiciousness).  Bit-exact against the host oracle under the
  (weight, id) tie-break; O(V) steps of O(E) work.  This is the faithful
  baseline recorded in EXPERIMENTS.md §Perf.

* :func:`bulk_peel` — **TPU-native bulk peeling** (beyond-paper
  optimization; Bahmani et al., VLDB'12 — the paper's own reference [2]):
  each round peels *every* active vertex with
  ``w_u <= 2(1+eps) * g(S)``, converging in O(log_{1+eps} V) rounds of
  pure streaming segment-sums over the edge-partitioned COO graph.  It
  carries a ``2(1+eps)``-approximation guarantee and is the form that
  scales to multi-pod meshes: per-round work is two masked
  ``segment_sum`` passes (HBM-bandwidth-bound) + an ``all_reduce`` of
  vertex deltas when edges are sharded.

Both return a *peel level* per vertex (sequential: the step index;
bulk: the round index) from which the detected community is the suffix
``level >= best_level``.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.graphstore.structs import DeviceGraph
from repro.kernels.peel_round.ops import peel_round

__all__ = [
    "PeelResultDevice",
    "exact_peel",
    "bulk_peel",
    "bulk_peel_warm",
    "bulk_peel_warm_workset",
    "bulk_peel_warm_checked",
    "select_bucket",
    "workset_sizes",
]

_INF = jnp.float32(jnp.inf)


class PeelResultDevice(NamedTuple):
    """Result of a device peel.

    ``level[u]``: step/round at which u was peeled (int32; padding = -1).
    ``best_level``: community = vertices with ``level >= best_level``.
    ``best_g``: density of the detected community.
    ``n_rounds``: rounds (bulk) or steps (exact) executed.
    ``order``: exact peel only — the peeling sequence (vertex ids), else
      zeros. ``delta``: peel-time weights aligned with ``order``/vertex id.
    """

    level: jax.Array
    best_level: jax.Array
    best_g: jax.Array
    n_rounds: jax.Array
    order: jax.Array
    delta: jax.Array

    def community_mask(self) -> jax.Array:
        return self.level >= self.best_level


# ---------------------------------------------------------------------------
# exact sequential peel (paper-faithful)
# ---------------------------------------------------------------------------


def exact_peel(g: DeviceGraph) -> PeelResultDevice:
    """Algorithm 1, one vertex per step, deterministic (w, id) tie-break."""
    V, E = g.n_capacity, g.e_capacity
    cm = jnp.where(g.edge_mask, g.c, 0.0)
    w0 = g.peel_weights()
    f0 = g.f_total()
    n0 = jnp.sum(g.vertex_mask)

    def body(i, carry):
        w, active, f, n_act, order, delta, level, best_g, best_i = carry
        key = jnp.where(active, w, _INF)
        u = jnp.argmin(key)  # ties -> lowest id (matches host oracle)
        wu = key[u]
        # density of the set *before* this peel
        g_cur = jnp.where(n_act > 0, f / jnp.maximum(n_act, 1), -_INF)
        improved = g_cur > best_g
        best_g = jnp.where(improved, g_cur, best_g)
        best_i = jnp.where(improved, i, best_i)

        live = jnp.where(active, 1.0, 0.0)
        touch_s = (g.src == u) & g.edge_mask
        touch_d = (g.dst == u) & g.edge_mask
        dw = jax.ops.segment_sum(
            jnp.where(touch_s, cm, 0.0) * live[g.dst], g.dst, num_segments=V
        ) + jax.ops.segment_sum(
            jnp.where(touch_d, cm, 0.0) * live[g.src], g.src, num_segments=V
        )
        peel_now = n_act > 0
        w = jnp.where(peel_now, w - dw, w)
        active = active & ~((jnp.arange(V) == u) & peel_now)
        order = order.at[i].set(jnp.where(peel_now, u, -1))
        delta = delta.at[i].set(jnp.where(peel_now, wu, 0.0))
        level = level.at[u].set(jnp.where(peel_now, i, level[u]))
        f = jnp.where(peel_now, f - wu, f)
        n_act = n_act - jnp.where(peel_now, 1, 0)
        return (w, active, f, n_act, order, delta, level, best_g, best_i)

    init = (
        w0,
        g.vertex_mask,
        f0,
        n0,
        jnp.full(V, -1, jnp.int32),
        jnp.zeros(V, jnp.float32),
        jnp.full(V, -1, jnp.int32),
        -_INF,
        jnp.int32(0),
    )
    w, active, f, n_act, order, delta, level, best_g, best_i = jax.lax.fori_loop(
        0, V, body, init
    )
    return PeelResultDevice(
        level=level,
        best_level=best_i,
        best_g=best_g,
        n_rounds=n0,
        order=order,
        delta=delta,
    )


# ---------------------------------------------------------------------------
# bulk parallel peel (TPU-native; 2(1+eps)-approximation)
# ---------------------------------------------------------------------------


class _BulkState(NamedTuple):
    w: jax.Array
    active: jax.Array
    edge_alive: jax.Array
    f: jax.Array
    n_act: jax.Array
    level: jax.Array
    best_g: jax.Array
    best_level: jax.Array
    round_: jax.Array


def _round_step(
    src: jax.Array,
    dst: jax.Array,
    c: jax.Array,
    a: jax.Array,
    eps: float,
    use_kernel: bool,
    s: _BulkState,
) -> _BulkState:
    """One bulk-peeling round over explicit COO arrays.

    Shared by the full-buffer round (``src/dst/c/a`` are the graph's
    capacity-padded buffers) and the workset round (the gathered affected
    suffix with locally relabeled endpoints) — one definition so the two
    engines cannot drift.

    (§Perf note: deriving edge liveness on the fly instead of carrying the
    [E] bool state was tried and REFUTED — two extra [E]-sized gathers +
    mask ops cost more HBM traffic than the stored array saves.)

    ``use_kernel`` routes the elementwise state update (threshold compare,
    weight subtract, active/level merge, peeled-mass partial sums) through
    the fused :func:`repro.kernels.peel_round.ops.peel_round` kernel
    (Pallas on TPU, pure-jnp reference elsewhere).  On integer weights the
    two paths are bit-identical; the flag exists so the kernel is exercised
    by the production round rather than staying interpret-only dead code.
    """
    V = s.w.shape[0]
    g_cur = s.f / jnp.maximum(s.n_act, 1).astype(jnp.float32)
    improved = (g_cur > s.best_g) & (s.n_act > 0)
    best_g = jnp.where(improved, g_cur, s.best_g)
    best_level = jnp.where(improved, s.round_, s.best_level)

    thresh = 2.0 * (1.0 + eps) * g_cur
    peel = s.active & (s.w <= thresh)
    # progress guarantee: avg_u w_u <= 2 g(S), so the min-weight vertex
    # always peels *in exact arithmetic*.  Under f32 the running f can
    # drift slightly negative on a nearly-drained set, pushing the
    # threshold below every remaining weight and stalling the while_loop;
    # force-peel the min-weight vertices then (a no-op whenever the
    # threshold test already fired, hence invisible on integer weights).
    wmin = jnp.min(jnp.where(s.active, s.w, _INF))
    eff_thresh = jnp.where(jnp.any(peel), thresh, wmin)
    peel = jnp.where(jnp.any(peel), peel, s.active & (s.w <= wmin))
    e_ps = peel[src]
    e_pd = peel[dst]
    cm = jnp.where(s.edge_alive, c, 0.0)
    # every edge with >= 1 peeled endpoint leaves the restricted set
    drop_mass = jnp.sum(jnp.where(e_ps | e_pd, cm, 0.0))
    # survivors lose suspiciousness of edges to peeled endpoints (the
    # round's SpMV: segment-sum form of the gather_segsum primitive)
    dw = jax.ops.segment_sum(
        jnp.where(e_ps & ~e_pd, cm, 0.0), dst, num_segments=V
    ) + jax.ops.segment_sum(jnp.where(e_pd & ~e_ps, cm, 0.0), src, num_segments=V)
    if use_kernel:
        # fused elementwise half: recomputes the same peel mask from
        # eff_thresh and applies the state update in one VMEM pass
        w, active, level, _, partials = peel_round(
            s.w, a, s.active, s.level, dw, eff_thresh, s.round_
        )
        f = s.f - partials[0] - drop_mass
        n_act = s.n_act - partials[2].astype(jnp.int32)
    else:
        w = s.w - dw
        active = s.active & ~peel
        level = jnp.where(peel, s.round_, s.level)
        # f loses peeled vertex weight + the dropped edge mass
        f = s.f - jnp.sum(jnp.where(peel, a, 0.0)) - drop_mass
        n_act = s.n_act - jnp.sum(peel)
    return _BulkState(
        w=w,
        active=active,
        edge_alive=s.edge_alive & ~(e_ps | e_pd),
        f=f,
        n_act=n_act,
        level=level,
        best_g=best_g,
        best_level=best_level,
        round_=s.round_ + 1,
    )


def _bulk_round(
    g: DeviceGraph, eps: float, s: _BulkState, use_kernel: bool = False
) -> _BulkState:
    """One full-buffer bulk-peeling round (see :func:`_round_step`)."""
    return _round_step(g.src, g.dst, g.c, g.a, eps, use_kernel, s)


@partial(jax.jit, static_argnames=("eps", "max_rounds", "unroll", "use_kernel"))
def bulk_peel(
    g: DeviceGraph,
    eps: float = 0.1,
    max_rounds: int = 0,
    unroll: bool = False,
    use_kernel: bool = False,
) -> PeelResultDevice:
    """Threshold bulk peeling; guarantees ``g_best >= g* / (2(1+eps))``.

    ``max_rounds = 0`` runs to completion (while_loop); a positive value
    bounds the round count (useful for fixed-cost serving ticks).
    ``unroll`` python-unrolls max_rounds rounds (roofline lowering).
    ``use_kernel`` routes the per-round elementwise update through the
    fused ``peel_round`` kernel (bit-identical on integer weights).
    """
    w0 = g.peel_weights()
    init = _BulkState(
        w=w0,
        active=g.vertex_mask,
        edge_alive=g.edge_mask,
        f=g.f_total(),
        n_act=jnp.sum(g.vertex_mask),
        level=jnp.full(g.n_capacity, -1, jnp.int32),
        best_g=-_INF,
        best_level=jnp.int32(0),
        round_=jnp.int32(0),
    )

    state = _run_rounds(
        partial(_bulk_round, g, eps, use_kernel=use_kernel), init, max_rounds, unroll
    )
    return PeelResultDevice(
        level=state.level,
        best_level=state.best_level,
        best_g=state.best_g,
        n_rounds=state.round_,
        order=jnp.zeros(g.n_capacity, jnp.int32),
        delta=state.w,
    )


def _run_rounds(round_fn, init, max_rounds: int, unroll: bool = False):
    if unroll and max_rounds:
        s = init
        for _ in range(max_rounds):
            s = round_fn(s)
        return s
    if max_rounds and max_rounds > 0:
        return jax.lax.fori_loop(0, max_rounds, lambda i, s: round_fn(s), init)
    return jax.lax.while_loop(lambda s: s.n_act > 0, round_fn, init)


def bulk_peel_warm(
    g: DeviceGraph,
    keep: jax.Array,
    prior_best_g: jax.Array,
    eps: float = 0.1,
    max_rounds: int = 0,
    unroll: bool = False,
    use_kernel: bool = False,
) -> PeelResultDevice:
    """Bulk peel restricted to ``keep`` vertices (warm start).

    Used by the incremental suffix re-peel: vertices outside ``keep`` are
    treated as already peeled; weights, f and n are recovered w.r.t. the
    restricted set, so every round's threshold is valid on the current set
    and the 2(1+eps) guarantee is preserved (DESIGN.md §2).  ``prior_best_g``
    seeds the best-density tracker so the maintained best never regresses.

    This is the **full-buffer** warm path: every round still streams the
    capacity-padded ``[E]``/``[V]`` buffers.  The workset twin
    (:func:`bulk_peel_warm_workset`) gathers the suffix into compact
    bucketed buffers first and is the steady-state serving path; this
    function remains the fallback when the suffix exceeds the largest
    bucket (DESIGN.md §8).
    """
    V = g.n_capacity
    live = keep & g.vertex_mask
    both = live[g.src] & live[g.dst] & g.edge_mask
    cm = jnp.where(both, g.c, 0.0)
    w0 = jnp.where(live, g.a, 0.0)
    w0 = w0 + jax.ops.segment_sum(cm, g.src, num_segments=V)
    w0 = w0 + jax.ops.segment_sum(cm, g.dst, num_segments=V)
    f0 = jnp.sum(jnp.where(live, g.a, 0.0)) + jnp.sum(cm)

    init = _BulkState(
        w=w0,
        active=live,
        edge_alive=both,
        f=f0,
        n_act=jnp.sum(live),
        level=jnp.full(V, -1, jnp.int32),
        best_g=prior_best_g.astype(jnp.float32),
        best_level=jnp.int32(0),
        round_=jnp.int32(0),
    )
    state = _run_rounds(
        partial(_bulk_round, g, eps, use_kernel=use_kernel), init, max_rounds, unroll
    )
    return PeelResultDevice(
        level=state.level,
        best_level=state.best_level,
        best_g=state.best_g,
        n_rounds=state.round_,
        order=jnp.zeros(V, jnp.int32),
        delta=state.w,
    )


# ---------------------------------------------------------------------------
# affected-area workset engine (the paper's §4 "affected area", materialized)
# ---------------------------------------------------------------------------
#
# A warm re-peel only ever touches the affected suffix ``keep``, yet the
# full-buffer round above streams all of ``[E]``/``[V]`` every round.  The
# workset engine gathers the suffix's live vertices and induced live edges
# into small fixed-capacity buffers once per tick, runs every round over
# those buffers only, and scatters ``level`` back — converting per-round
# work from O(E_capacity) to O(|affected suffix|).  Buffer sizes come from
# a power-of-two bucket ladder so the number of distinct jit compilations
# is O(log E), not O(E) (DESIGN.md §8).


def select_bucket(count: int, capacity: int, floor: int = 64) -> int | None:
    """Pick the power-of-two workset bucket for ``count`` elements.

    Returns the smallest power of two ``>= max(count, floor)``, or ``None``
    when ``count`` exceeds the largest bucket — the largest power of two
    ``<= max(capacity // 2, floor)``.  A workset larger than half the
    backing buffer cannot meaningfully beat streaming the buffer itself,
    so the caller falls through to the full-buffer warm path.  Host-side
    pure function: callers sync the (tiny) count scalar, pick the bucket,
    and dispatch the statically-shaped jitted variant.
    """
    if count < 0:
        raise ValueError(f"negative workset count {count}")
    largest = max(capacity // 2, floor)
    largest = 1 << (largest.bit_length() - 1)  # round DOWN to a power of two
    if count > largest:
        return None
    bucket = max(count, floor)
    return 1 << (bucket - 1).bit_length()  # round UP to a power of two


@partial(jax.jit)
def workset_sizes(g: DeviceGraph, keep: jax.Array) -> tuple[jax.Array, jax.Array]:
    """(live suffix vertices, suffix-induced live edges) — the two counts
    bucket selection needs, as device scalars (one fused reduction pass)."""
    live = keep & g.vertex_mask
    both = live[g.src] & live[g.dst] & g.edge_mask
    return jnp.sum(live).astype(jnp.int32), jnp.sum(both).astype(jnp.int32)


class Workset(NamedTuple):
    """The gathered affected suffix (all leading dims are bucket-sized).

    ``vid[j]``: global id of local vertex ``j`` (= ``n_capacity`` on pad
    lanes, so scatter-back drops them).  Edge endpoints are local ids; pad
    edge lanes carry ``c = 0`` / ``alive = False`` and endpoint 0 (inert:
    zero suspiciousness contributes nothing to any segment).
    """

    vid: jax.Array  # int32 [Bv]
    a: jax.Array  # float32 [Bv]
    active: jax.Array  # bool [Bv]
    src: jax.Array  # int32 [Be] local
    dst: jax.Array  # int32 [Be] local
    c: jax.Array  # float32 [Be]
    alive: jax.Array  # bool [Be]


def _compact_workset(
    src: jax.Array,
    dst: jax.Array,
    c: jax.Array,
    emask: jax.Array,
    a: jax.Array,
    live: jax.Array,
    v_bucket: int,
    e_bucket: int,
) -> Workset:
    """Compact the affected suffix into bucket-sized buffers.

    The k-th live vertex (in id order) gets local id k — the same dense
    slot semantics as ``compact_slots``/``remove_edges``, so the local
    order is deterministic and shard-independent.  Like ``remove_edges``,
    the compaction is a **gather**: workset lane ``k`` locates the k-th
    live vertex/edge by binary search over a prefix sum — no [E]-sized
    scatter touches the tick's critical path.  Callers guarantee (via
    :func:`select_bucket`) that the counts fit the buckets.

    Takes raw COO arrays so the sharded engine can reuse it verbatim with
    a shard's *local* edge block (vertex arrays replicated): one
    definition of the gather for both planes.
    """
    V = a.shape[0]
    vsum = jnp.cumsum(live.astype(jnp.int32))  # [V]
    local = vsum - 1  # local id per live vertex
    nv = vsum[V - 1]
    vlane = jnp.arange(v_bucket, dtype=jnp.int32)
    vid = jnp.searchsorted(vsum, vlane + 1).astype(jnp.int32)
    active0 = vlane < nv
    vid = jnp.where(active0, vid, V)  # pad lanes dropped on scatter-back
    a_ws = a.at[vid].get(mode="fill", fill_value=0.0)

    both = live[src] & live[dst] & emask
    esum = jnp.cumsum(both.astype(jnp.int32))  # [E]
    ne = esum[src.shape[0] - 1]
    elane = jnp.arange(e_bucket, dtype=jnp.int32)
    eidx = jnp.searchsorted(esum, elane + 1).astype(jnp.int32)
    alive0 = elane < ne
    eidx = jnp.where(alive0, eidx, 0)  # clamp; pad lanes masked below
    # pad edge lanes: endpoint 0 with c = 0 is inert in every segment op
    lsrc = jnp.where(alive0, local[src[eidx]], 0)
    ldst = jnp.where(alive0, local[dst[eidx]], 0)
    c_ws = jnp.where(alive0, c[eidx], 0.0)
    return Workset(vid=vid, a=a_ws, active=active0, src=lsrc, dst=ldst,
                   c=c_ws, alive=alive0)


def _gather_workset(
    g: DeviceGraph, keep: jax.Array, v_bucket: int, e_bucket: int
) -> Workset:
    live = keep & g.vertex_mask
    return _compact_workset(g.src, g.dst, g.c, g.edge_mask, g.a, live,
                            v_bucket, e_bucket)


@partial(
    jax.jit,
    static_argnames=("eps", "max_rounds", "unroll", "v_bucket", "e_bucket",
                     "use_kernel"),
)
def bulk_peel_warm_workset(
    g: DeviceGraph,
    keep: jax.Array,
    prior_best_g: jax.Array,
    eps: float = 0.1,
    max_rounds: int = 0,
    unroll: bool = False,
    *,
    v_bucket: int,
    e_bucket: int,
    use_kernel: bool = False,
) -> PeelResultDevice:
    """Workset twin of :func:`bulk_peel_warm`: gather → peel → scatter.

    Bit-identical to the full-buffer warm peel on integer weights: the
    workset holds exactly the suffix's live vertices and induced live
    edges, every per-vertex/per-set quantity is the same integer sum (f32
    sums of integers are exact in any order), and the round sequence is
    driven by those quantities only.  See DESIGN.md §8 for the correctness
    argument across the scatter-back.
    """
    V = g.n_capacity
    ws = _gather_workset(g, keep, v_bucket, e_bucket)
    cm0 = jnp.where(ws.alive, ws.c, 0.0)
    w0 = ws.a + jax.ops.segment_sum(cm0, ws.src, num_segments=v_bucket)
    w0 = w0 + jax.ops.segment_sum(cm0, ws.dst, num_segments=v_bucket)
    f0 = jnp.sum(ws.a) + jnp.sum(cm0)

    init = _BulkState(
        w=w0,
        active=ws.active,
        edge_alive=ws.alive,
        f=f0,
        n_act=jnp.sum(ws.active),
        level=jnp.full(v_bucket, -1, jnp.int32),
        best_g=prior_best_g.astype(jnp.float32),
        best_level=jnp.int32(0),
        round_=jnp.int32(0),
    )
    state = _run_rounds(
        partial(_round_step, ws.src, ws.dst, ws.c, ws.a, eps, use_kernel),
        init, max_rounds, unroll,
    )
    # scatter the suffix results back to full-width vertex arrays; pad
    # lanes carry vid = V and are dropped
    level = jnp.full(V, -1, jnp.int32).at[ws.vid].set(state.level, mode="drop")
    delta = jnp.zeros(V, jnp.float32).at[ws.vid].set(state.w, mode="drop")
    return PeelResultDevice(
        level=level,
        best_level=state.best_level,
        best_g=state.best_g,
        n_rounds=state.round_,
        order=jnp.zeros(V, jnp.int32),
        delta=delta,
    )


@partial(
    jax.jit,
    static_argnames=("eps", "max_rounds", "v_bucket", "e_bucket", "use_kernel"),
)
def bulk_peel_warm_checked(
    g: DeviceGraph,
    keep: jax.Array,
    prior_best_g: jax.Array,
    nv: jax.Array,
    ne: jax.Array,
    eps: float = 0.1,
    max_rounds: int = 0,
    *,
    v_bucket: int,
    e_bucket: int,
    use_kernel: bool = False,
) -> tuple[PeelResultDevice, jax.Array]:
    """Warm peel with a *device-side* bucket-fit check — the primitive the
    predictive workset dispatcher builds on.

    ``v_bucket/e_bucket`` come from the host's *prediction* (previous-tick
    suffix counts), not from this tick's synced counts; ``nv/ne`` are this
    tick's actual counts, still resident on device.  ``lax.cond`` selects
    between the workset path (counts fit the predicted buckets — the
    gather is lossless) and the full-buffer warm peel (bucket miss — the
    always-correct fallback), so the host never has to block on the count
    transfer before dispatching the re-peel.  Both branches return the
    full-width ``PeelResultDevice``; on integer weights they are
    bit-identical whenever both are applicable, so a miss costs time,
    never correctness.

    Returns ``(result, fits)`` with ``fits`` the device bool the caller
    can drain lazily for telemetry.
    """
    fits = (nv <= jnp.int32(v_bucket)) & (ne <= jnp.int32(e_bucket))
    res = jax.lax.cond(
        fits,
        lambda: bulk_peel_warm_workset(
            g, keep, prior_best_g, eps=eps, max_rounds=max_rounds,
            v_bucket=v_bucket, e_bucket=e_bucket, use_kernel=use_kernel,
        ),
        lambda: bulk_peel_warm(
            g, keep, prior_best_g, eps=eps, max_rounds=max_rounds,
            use_kernel=use_kernel,
        ),
    )
    return res, fits
