"""The Spade public API (paper Listing 1), host plane.

``Spade`` wraps the exact incremental engine (:mod:`repro.core.reference`)
with the developer-facing surface from the paper:

* ``VSusp`` / ``ESusp``      — plug in fraud semantics (or pass a
  :class:`~repro.core.semantics.SuspSemantics` / a registered name / a
  host-only :class:`~repro.core.metrics.DensityMetric`).  A
  ``SuspSemantics`` is compiled into the host funnel through its
  :meth:`~repro.core.semantics.SuspSemantics.host_metric` adapter — the
  same definition the device/sharded/workset engines compile, so this
  class is a thin adapter over the semantics plane.
* ``Detect``                 — current fraudulent community S^P.
* ``InsertEdge`` / ``InsertBatchEdges`` — incremental maintenance.
* ``DeleteEdge``             — incremental deletion (Appendix C.1); with
  inserts this composes into time-window detection (C.3).
* ``TurnOnEdgeGrouping``     — benign/urgent routing (§4.3, Def 4.1):
  benign edges queue in a buffer, urgent edges flush the buffer and trigger
  immediate reordering.

The class maintains ``w0[u] = w_u(S_0)`` (full-graph peeling weight)
incrementally in O(1) per edge for the benign test, and a conservative
cache of ``g(S^P)`` that is refreshed exactly by every ``Detect``/reorder.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Iterable, Sequence

import numpy as np

from .metrics import DensityMetric, make_metric, quantize_susp
from .reference import (
    AdjGraph,
    PeelState,
    ReorderStats,
    delete_edge,
    detect,
    insert_edges,
    static_peel,
)

__all__ = ["Spade", "InsertResult"]


@dataclass
class InsertResult:
    """Outcome of one Insert call."""

    fraudsters: np.ndarray  # current community S^P (vertex ids)
    g_best: float
    triggered: bool  # did this call run a reorder (False: buffered benign)
    buffered: int  # edges currently waiting in the benign buffer
    new_fraudsters: np.ndarray = field(
        default_factory=lambda: np.empty(0, dtype=np.int64)
    )
    stats: ReorderStats | None = None
    reorder_seconds: float = 0.0


class Spade:
    """Real-time fraud detection on an evolving transaction graph."""

    def __init__(self, metric="FD", edge_grouping: bool = False):
        # accepts a registered name, a SuspSemantics, or a DensityMetric —
        # make_metric funnels all three through the one semantics registry
        self._metric = make_metric(metric)
        self._g = AdjGraph(0)
        self._state: PeelState | None = None
        self._edge_grouping = bool(edge_grouping)
        self._benign_edges: list[tuple[int, int, float]] = []
        self._benign_new_vertices: list[tuple[int, float]] = []
        self._w0 = np.zeros(0, dtype=np.float64)  # w_u(S_0), maintained O(1)/edge
        self._known = np.zeros(0, dtype=bool)
        self._prev_community: set[int] = set()

    # -- paper API -----------------------------------------------------------

    def VSusp(self, fn) -> None:
        self._metric = DensityMetric(self._metric.name, fn, self._metric.esusp)

    def ESusp(self, fn) -> None:
        self._metric = DensityMetric(self._metric.name, self._metric.vsusp, fn)

    def TurnOnEdgeGrouping(self) -> None:
        self._edge_grouping = True

    def LoadGraph(
        self,
        src: Sequence[int],
        dst: Sequence[int],
        raw_weight: Sequence[float] | None = None,
        n_vertices: int | None = None,
    ) -> None:
        """Build the initial graph and run the static peel (Algorithm 1)."""
        src = np.asarray(src, dtype=np.int64)
        dst = np.asarray(dst, dtype=np.int64)
        raw = (
            np.asarray(raw_weight, dtype=np.float64)
            if raw_weight is not None
            else np.ones(src.shape[0])
        )
        n = int(n_vertices if n_vertices is not None else (max(src.max(initial=-1), dst.max(initial=-1)) + 1))
        g = AdjGraph(n)
        for u in range(n):
            g.a[u] = self._metric.vertex_susp(u, g)
        for u, v, r in zip(src.tolist(), dst.tolist(), raw.tolist()):
            g.add_edge(int(u), int(v), self._metric.edge_susp(int(u), int(v), float(r), g))
        self._g = g
        self._state = static_peel(g)
        self._w0 = self._recompute_w0()
        detect(self._state)
        self._prev_community = set(self.Detect()[0].tolist())

    def Detect(self) -> tuple[np.ndarray, float]:
        """Current fraudulent community S^P and its density g(S^P)."""
        self._require_loaded()
        return detect(self._state)

    def InsertEdge(self, u: int, v: int, raw_weight: float = 1.0) -> InsertResult:
        return self.InsertBatchEdges([(u, v, raw_weight)])

    def InsertBatchEdges(
        self, edges: Iterable[tuple[int, int, float]]
    ) -> InsertResult:
        """Insert transactions; route through edge grouping when enabled."""
        self._require_loaded()
        pending_edges: list[tuple[int, int, float]] = []
        pending_new: list[tuple[int, float]] = []
        any_urgent = False
        for u, v, raw in edges:
            u, v = int(u), int(v)
            pending_new.extend(self._admit_vertices(u, v, pending=pending_new))
            c = self._metric.edge_susp(u, v, float(raw), self._g)
            pending_edges.append((u, v, c))
            # O(1) benign/urgent test (Def 4.1) against the cached g(S^P)
            urgent = (
                self._w0_of(u) + c >= self._state.g_best_cache
                or self._w0_of(v) + c >= self._state.g_best_cache
            )
            self._w0_add(u, c)
            self._w0_add(v, c)
            any_urgent = any_urgent or urgent

        if self._edge_grouping and not any_urgent:
            self._benign_edges.extend(pending_edges)
            self._benign_new_vertices.extend(pending_new)
            return InsertResult(
                fraudsters=np.empty(0, dtype=np.int64),
                g_best=self._state.g_best_cache,
                triggered=False,
                buffered=len(self._benign_edges),
            )

        # urgent (or grouping off): flush buffer + this batch, reorder now
        batch_edges = self._benign_edges + pending_edges
        batch_new = self._benign_new_vertices + pending_new
        self._benign_edges, self._benign_new_vertices = [], []
        return self._reorder_and_detect(batch_edges, batch_new)

    def DeleteEdge(self, u: int, v: int, c: float | None = None) -> InsertResult:
        """Delete (all or ``c`` of) the combined edge weight between ``u``
        and ``v`` and reorder incrementally (paper Appendix C.1).

        ``c`` is in *suspiciousness units* — the stored adjacency weight,
        i.e. what ``ESusp`` returned at arrival time (for DW that is the
        grid-snapped raw amount; for FD the arrival-time degree weighting,
        which cannot be recomputed from a raw amount later).  It is
        snapped to the same dyadic grid as every stored weight, so passing
        the original raw DW amount deletes the edge exactly instead of
        tripping the more-than-present check or leaving a sub-quantum
        residual edge.

        The benign buffer is flushed first: a buffered edge may be the one
        being expired, and the deletion invalidates the cached g(S^P) the
        buffered edges were classified against — flushing re-anchors both.
        Composed with ``InsertEdge`` this is the paper's C.3 time-window
        maintenance on the host plane.
        """
        self._require_loaded()
        if self._benign_edges or self._benign_new_vertices:
            self.FlushBuffer()
        u, v = int(u), int(v)
        if u >= self._g.n or v >= self._g.n:
            # match delete_edge's missing-edge contract instead of letting
            # the adjacency lookup die with a bare IndexError
            raise KeyError(f"no edge between {u} and {v}")
        if c is not None:
            c = quantize_susp(float(c))
        w_before = self._g.adj[u].get(v, 0.0)
        t0 = time.perf_counter()
        stats = delete_edge(self._state, u, v, c)
        dt = time.perf_counter() - t0
        w_removed = w_before - self._g.adj[u].get(v, 0.0)
        # O(1) w0 maintenance, mirroring the insert path's increment
        self._w0_add(u, -w_removed)
        self._w0_add(v, -w_removed)
        comm, gb = detect(self._state)
        comm_set = set(comm.tolist())
        new_f = np.asarray(sorted(comm_set - self._prev_community), dtype=np.int64)
        self._prev_community = comm_set
        return InsertResult(
            fraudsters=comm,
            g_best=gb,
            triggered=True,
            buffered=0,
            new_fraudsters=new_f,
            stats=stats,
            reorder_seconds=dt,
        )

    def FlushBuffer(self) -> InsertResult:
        """Force-process all buffered benign edges (periodic batch tick)."""
        self._require_loaded()
        batch_edges, batch_new = self._benign_edges, self._benign_new_vertices
        self._benign_edges, self._benign_new_vertices = [], []
        if not batch_edges and not batch_new:
            comm, gb = self.Detect()
            return InsertResult(comm, gb, triggered=False, buffered=0)
        return self._reorder_and_detect(batch_edges, batch_new)

    # -- internals -------------------------------------------------------------

    @property
    def graph(self) -> AdjGraph:
        return self._g

    @property
    def state(self) -> PeelState:
        self._require_loaded()
        return self._state

    @property
    def metric(self) -> DensityMetric:
        return self._metric

    @property
    def buffered_edges(self) -> int:
        return len(self._benign_edges)

    def _require_loaded(self) -> None:
        if self._state is None:
            raise RuntimeError("call LoadGraph first")

    def _admit_vertices(
        self, *vids: int, pending: Sequence[tuple[int, float]] = ()
    ) -> list[tuple[int, float]]:
        """Vertices not yet in the graph are scheduled for head insertion.

        ``pending`` holds vertices already admitted by earlier edges of the
        *current* batch (they are not yet in ``_benign_new_vertices``), so
        a batch introducing several new vertices via separate edges counts
        them toward the next dense id.
        """
        out: list[tuple[int, float]] = []
        for vid in sorted(set(vids)):
            next_id = (
                self._g.n + len(out) + len(pending) + len(self._benign_new_vertices)
            )
            if vid > next_id:
                # ids must arrive densely; generators guarantee this
                raise ValueError(f"vertex id {vid} skips ahead of next id {next_id}")
            if vid >= self._g.n:
                already = (
                    any(x[0] == vid for x in self._benign_new_vertices)
                    or any(x[0] == vid for x in pending)
                    or any(x[0] == vid for x in out)
                )
                if not already:
                    a = self._metric.vertex_susp(vid, self._g)
                    out.append((vid, a))
                    self._w0_add(vid, a)
        return out

    def _reorder_and_detect(
        self,
        batch_edges: list[tuple[int, int, float]],
        batch_new: list[tuple[int, float]],
    ) -> InsertResult:
        t0 = time.perf_counter()
        stats = insert_edges(self._state, batch_edges, batch_new)
        dt = time.perf_counter() - t0
        comm, gb = detect(self._state)
        comm_set = set(comm.tolist())
        new_f = np.asarray(sorted(comm_set - self._prev_community), dtype=np.int64)
        self._prev_community = comm_set
        return InsertResult(
            fraudsters=comm,
            g_best=gb,
            triggered=True,
            buffered=0,
            new_fraudsters=new_f,
            stats=stats,
            reorder_seconds=dt,
        )

    def _recompute_w0(self) -> np.ndarray:
        from .reference import peeling_weights_full

        w0 = np.zeros(max(self._g.n, 1), dtype=np.float64)
        w0[: self._g.n] = peeling_weights_full(self._g)
        return w0

    def _w0_of(self, u: int) -> float:
        if u >= self._w0.shape[0]:
            return 0.0
        return float(self._w0[u])

    def _w0_add(self, u: int, c: float) -> None:
        if u >= self._w0.shape[0]:
            grow = max(256, u + 1 - self._w0.shape[0])
            self._w0 = np.concatenate([self._w0, np.zeros(grow)])
        self._w0[u] += c
