"""Device-plane incremental maintenance (the paper's §4, TPU-native).

The host oracle reorders an explicit peeling sequence with a pending heap;
on TPU the same *affected-area* idea becomes a **warm suffix re-peel**:

1. Each vertex carries the ``level`` (bulk-peel round) at which it was
   peeled during the last maintenance pass.  The set
   ``{u : level[u] >= r}`` is exactly the active set at the start of round
   ``r`` (nested family — the vectorized analogue of the peel sequence).
2. An inserted batch only raises the weights of its endpoints (Lemma 4.1's
   vectorized form); with ``r0 = min_{endpoints} level``, every set before
   round ``r0`` is untouched, so maintenance re-peels only
   ``keep = level >= r0`` with weights/f recovered w.r.t. that suffix.
3. Thresholds inside the warm re-peel are computed on the *current*
   restricted set, so each round remains a valid generalized peeling step
   and the global ``2(1+eps)`` guarantee is preserved (proof sketch in
   DESIGN.md §2); the maintained best density never regresses because
   insertions only increase ``f`` of any set containing the endpoints.

New vertices are admitted with ``level = INT32_MAX`` (always inside the
re-peeled suffix without dragging ``r0`` down).
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.peel import (
    PeelResultDevice,
    bulk_peel,
    bulk_peel_warm,
    bulk_peel_warm_checked,
    bulk_peel_warm_workset,
    select_bucket,
    workset_sizes,
)
from repro.graphstore.structs import DeviceGraph, append_edges, remove_edges

__all__ = [
    "DeviceSpadeState",
    "WorksetTickInfo",
    "BucketPredictor",
    "init_state",
    "insert_and_maintain",
    "insert_and_maintain_auto",
    "insert_and_maintain_predictive",
    "delete_and_maintain",
    "slide_and_maintain",
    "slide_and_maintain_auto",
    "slide_and_maintain_predictive",
    "full_refresh",
    "benign_mask",
]

_LEVEL_NEW = jnp.int32(2**31 - 1)


@partial(
    jax.tree_util.register_dataclass,
    data_fields=["graph", "level", "best_g", "community", "edge_count", "w0"],
    meta_fields=[],
)
@dataclasses.dataclass(frozen=True)
class DeviceSpadeState:
    """Evolving-graph fraud-detection state (pure pytree, donate-friendly).

    ``w0[u]`` mirrors the full-graph peeling weight ``w_u(S_0)`` for the
    O(1) benign/urgent test (Def 4.1).
    """

    graph: DeviceGraph
    level: jax.Array  # int32 [V_cap] peel round per vertex
    best_g: jax.Array  # float32 scalar — maintained best density
    community: jax.Array  # bool [V_cap] — maintained S^P
    edge_count: jax.Array  # int32 scalar — next free edge slot
    w0: jax.Array  # float32 [V_cap]


def init_state(g: DeviceGraph, eps: float = 0.1) -> DeviceSpadeState:
    """Static bulk peel (Algorithm 1, bulk form) to seed the state."""
    res = bulk_peel(g, eps=eps)
    return DeviceSpadeState(
        graph=g,
        level=res.level,
        best_g=res.best_g,
        community=res.community_mask() & g.vertex_mask,
        edge_count=jnp.sum(g.edge_mask).astype(jnp.int32),
        w0=g.peel_weights(),
    )


def benign_mask(state: DeviceSpadeState, src, dst, c) -> jax.Array:
    """Vectorized Def 4.1: an edge is benign iff *both* endpoint tests fail
    the urgency condition ``w_u(S_0) + c >= g(S^P)``."""
    urgent = (state.w0[src] + c >= state.best_g) | (state.w0[dst] + c >= state.best_g)
    return ~urgent


class _SlideBookkeeping(NamedTuple):
    """Replicated pre-re-peel bookkeeping shared by the single-device and
    the mesh-sharded window-slide paths (one definition so the two engines
    cannot drift — the same role ``compact_slots`` plays for appends)."""

    dropped: jax.Array  # [E] live slots being expired
    cd: jax.Array  # [E] expired suspiciousness (0 elsewhere)
    n_new: jax.Array
    r0: jax.Array
    keep: jax.Array
    prior_g: jax.Array


def _slide_prologue(
    state: DeviceSpadeState, drop: jax.Array | None, src, dst, valid
) -> _SlideBookkeeping:
    """``drop = None`` marks an insert-only tick at trace time: the dropped
    bookkeeping collapses to inert zeros and the [E]-sized passes over the
    drop mask are elided from the program entirely."""
    g0 = state.graph
    n_new = jnp.sum(valid).astype(jnp.int32)
    if drop is None:
        dropped = jnp.zeros(g0.e_capacity, bool)
        n_del = jnp.int32(0)
        cd = jnp.zeros(g0.e_capacity, jnp.float32)
        lvl = _LEVEL_NEW
        comm_loss = jnp.float32(0.0)
    else:
        dropped = drop & g0.edge_mask
        n_del = jnp.sum(dropped).astype(jnp.int32)
        cd = jnp.where(dropped, g0.c, 0.0)
        # affected suffix start: min endpoint level over dropped AND
        # inserted edges (both endpoint sets sit inside the suffix)
        lvl = jnp.minimum(
            jnp.min(jnp.where(dropped, state.level[g0.src], _LEVEL_NEW)),
            jnp.min(jnp.where(dropped, state.level[g0.dst], _LEVEL_NEW)),
        )
        # exact density loss of the old community in the post-deletion
        # graph: the dropped mass with both endpoints inside S^P
        in_comm = state.community[g0.src] & state.community[g0.dst]
        comm_loss = jnp.sum(jnp.where(dropped & in_comm, g0.c, 0.0))
    lvl = jnp.minimum(lvl, jnp.min(jnp.where(valid, state.level[src], _LEVEL_NEW)))
    lvl = jnp.minimum(lvl, jnp.min(jnp.where(valid, state.level[dst], _LEVEL_NEW)))
    r0 = jnp.where((n_del > 0) | (n_new > 0), lvl, _LEVEL_NEW)
    r0 = jnp.minimum(r0, jnp.int32(2**30))

    # re-seed the best tracker with the old community's exact post-deletion
    # density (stale-low if best_g was already conservative — only ever
    # under-reports, never hides fraud); deletion may legally regress it
    n_comm = jnp.sum(state.community).astype(jnp.float32)
    prior_g = jnp.where(
        n_comm > 0, state.best_g - comm_loss / jnp.maximum(n_comm, 1.0),
        -jnp.float32(jnp.inf),
    )
    return _SlideBookkeeping(
        dropped=dropped, cd=cd, n_new=n_new, r0=r0,
        keep=state.level >= r0, prior_g=prior_g,
    )


def _slide_epilogue(
    state: DeviceSpadeState,
    g: DeviceGraph,
    res: PeelResultDevice,
    bk: _SlideBookkeeping,
    n_removed: jax.Array,
    src, dst, c, valid,
    with_drops: bool = True,
    d_bucket: int = 0,
) -> DeviceSpadeState:
    """Merge a warm re-peel back into the state (level rebase, community
    update, exact w0 decrement/increment, edge-counter move).

    ``with_drops = False`` (insert-only ticks) statically elides the
    dropped-mass w0 decrement, restoring in-place donation of the edge
    buffers (the decrement gathers pre-update ``src/dst``, which otherwise
    blocks XLA from reusing them for the appended graph).

    ``d_bucket > 0`` (workset dispatch; the host has synced the dropped
    count) compacts the dropped edges into a ``d_bucket``-sized buffer by
    the same searchsorted gather the workset uses, so the decrement
    scatter-adds O(dropped) updates instead of O(E_capacity) — on a
    steady-state tick the dropped batch is ~1k lanes of a ~400k buffer.
    Identical sums on integer weights; scatter-add order may differ
    otherwise (the same reduction-order caveat as the sharded engine)."""
    g0 = state.graph
    suffix_level = jnp.where(res.level >= 0, res.level, res.n_rounds)
    new_level = jnp.where(bk.keep, bk.r0 + suffix_level, state.level)
    improved = res.best_g > bk.prior_g
    new_comm = jnp.where(
        improved,
        (res.level >= res.best_level) & bk.keep & g.vertex_mask,
        state.community,
    )
    # exact on integer weights; padding lanes carry cd = 0 / cv = 0
    w0 = state.w0
    if with_drops and d_bucket:
        dsum = jnp.cumsum(bk.dropped.astype(jnp.int32))
        nd = dsum[g0.e_capacity - 1]
        lane = jnp.arange(d_bucket, dtype=jnp.int32)
        didx = jnp.searchsorted(dsum, lane + 1).astype(jnp.int32)
        dlive = lane < nd
        didx = jnp.where(dlive, didx, 0)
        pad = jnp.int32(g0.n_capacity)  # out of range -> dropped by scatter
        dsrc = jnp.where(dlive, g0.src[didx], pad)
        ddst = jnp.where(dlive, g0.dst[didx], pad)
        dc = jnp.where(dlive, bk.cd[didx], 0.0)
        w0 = w0.at[dsrc].add(-dc, mode="drop")
        w0 = w0.at[ddst].add(-dc, mode="drop")
    elif with_drops:
        w0 = w0.at[g0.src].add(-bk.cd, mode="drop")
        w0 = w0.at[g0.dst].add(-bk.cd, mode="drop")
    cv = jnp.where(valid, c.astype(jnp.float32), 0.0)
    w0 = w0.at[src].add(cv, mode="drop")
    w0 = w0.at[dst].add(cv, mode="drop")
    return DeviceSpadeState(
        graph=g,
        level=new_level,
        best_g=jnp.maximum(res.best_g, bk.prior_g),
        community=new_comm,
        edge_count=state.edge_count - n_removed + bk.n_new,
        w0=w0,
    )


@partial(jax.jit, static_argnames=("eps", "max_rounds", "unroll"),
         donate_argnames=("state",))
def insert_and_maintain(
    state: DeviceSpadeState,
    src: jax.Array,
    dst: jax.Array,
    c: jax.Array,
    valid: jax.Array,
    eps: float = 0.1,
    max_rounds: int = 0,
    unroll: bool = False,
) -> DeviceSpadeState:
    """Insert an edge batch and maintain the community incrementally.

    ``src/dst/c`` are fixed-size batch arrays with a ``valid`` mask
    (streaming ticks pad to the batch size).  One fused device program:
    append -> affected-suffix recovery -> warm bulk re-peel -> state merge.
    The suffix/merge bookkeeping is the shared ``_slide_prologue`` /
    ``_slide_epilogue`` with an empty drop mask (insertion is a window
    slide that expires nothing — one definition for insert/delete/slide,
    so the three paths cannot drift); unlike the slide the live prefix is
    untouched, so the compaction pass is skipped entirely.
    """
    bk = _slide_prologue(state, None, src, dst, valid)
    g = append_edges(state.graph, state.edge_count, src, dst, c, valid=valid)
    res = bulk_peel_warm(g, bk.keep, prior_best_g=bk.prior_g, eps=eps,
                         max_rounds=max_rounds, unroll=unroll)
    return _slide_epilogue(state, g, res, bk, jnp.int32(0), src, dst, c, valid,
                           with_drops=False)


def delete_and_maintain(
    state: DeviceSpadeState,
    drop: jax.Array,
    eps: float = 0.1,
    max_rounds: int = 0,
    unroll: bool = False,
) -> DeviceSpadeState:
    """Delete the edges in slot mask ``drop`` and maintain incrementally.

    The deletion mirror of :func:`insert_and_maintain` (paper Appendix C.1,
    vectorized — DESIGN.md §6): deleted edges only *lower* the weights of
    their endpoints, and with ``r0 = min_{endpoints} level`` both endpoints
    of every dropped edge sit inside the suffix ``level >= r0``, so no
    prefix vertex's peel-time weight changes and only the suffix is
    re-peeled.  Unlike insertion the maintained best density may legally
    *regress*: the tracker is re-seeded with the exact density of the
    previous community in the post-deletion graph (its stored value minus
    the dropped mass with both endpoints inside it) rather than the stale
    pre-deletion value.  ``remove_edges`` compacts the surviving slots to
    the buffer prefix, so the edge counter simply shrinks by the number of
    live edges dropped.

    Exactly a window slide with an empty insert batch (the shared jitted
    program handles both).
    """
    z = jnp.zeros(1, jnp.int32)
    return slide_and_maintain(
        state, drop, z, z, z.astype(jnp.float32), jnp.zeros(1, bool),
        eps=eps, max_rounds=max_rounds, unroll=unroll,
    )


@partial(jax.jit, static_argnames=("eps", "max_rounds", "unroll"),
         donate_argnames=("state",))
def slide_and_maintain(
    state: DeviceSpadeState,
    drop: jax.Array,
    src: jax.Array,
    dst: jax.Array,
    c: jax.Array,
    valid: jax.Array,
    eps: float = 0.1,
    max_rounds: int = 0,
    unroll: bool = False,
) -> DeviceSpadeState:
    """One fused sliding-window tick: expire ``drop``, insert the batch,
    re-peel **once** (paper Appendix C.3, vectorized).

    Composing :func:`delete_and_maintain` + :func:`insert_and_maintain`
    would re-peel the affected suffix twice per tick; here ``r0`` is the
    minimum endpoint level over dropped *and* inserted edges, so a single
    warm re-peel covers both updates — the steady-state serving loop does
    one device program per tick.  Bookkeeping composes the two paths:
    ``w0`` is decremented by dropped mass and incremented by inserted
    mass, the best-density tracker is re-seeded with the old community's
    exact post-deletion density (DESIGN.md §6), and the edge counter
    shrinks by the dropped count and grows by the inserted count.
    """
    bk = _slide_prologue(state, drop, src, dst, valid)
    g, n_removed = remove_edges(state.graph, drop)
    g = append_edges(g, state.edge_count - n_removed, src, dst, c, valid=valid)
    res = bulk_peel_warm(g, bk.keep, prior_best_g=bk.prior_g, eps=eps,
                         max_rounds=max_rounds, unroll=unroll)
    return _slide_epilogue(state, g, res, bk, n_removed, src, dst, c, valid)


# ---------------------------------------------------------------------------
# workset dispatch: gather the affected suffix, peel the workset only
# ---------------------------------------------------------------------------
#
# The fused programs above stream the full capacity-padded buffers every
# round.  The workset engine (DESIGN.md §8) splits a tick into two device
# programs: phase A applies the structural update and counts the affected
# suffix; the host syncs the two count scalars, picks power-of-two buckets
# (O(log E) jitted variants), and dispatches phase B — the warm re-peel
# over the gathered workset, or the full-buffer path when the suffix
# exceeds the largest bucket.


class WorksetTickInfo(NamedTuple):
    """Host-side telemetry for one auto-dispatched maintenance tick.

    ``n_suffix_edges`` is the global suffix-induced live-edge count on a
    single device but the MAX **per-shard** count under a mesh (the
    sharded engine buckets each shard's local workset; see
    ``sharded_workset_sizes``) — compare across modes accordingly.
    """

    n_suffix_vertices: int
    n_suffix_edges: int
    v_bucket: int  # 0 on fallback
    e_bucket: int  # 0 on fallback
    fallback: bool
    # predictive dispatch (BucketPredictor): buckets were chosen from the
    # previous tick's counts without waiting for this tick's sync; a miss
    # (counts outgrew the prediction) rode the in-program full-buffer
    # fallback — correct, just slower — and re-anchored the predictor
    predicted: bool = False
    miss: bool = False


@jax.jit
def _insert_phase_a(state, src, dst, c, valid):
    bk = _slide_prologue(state, None, src, dst, valid)
    g = append_edges(state.graph, state.edge_count, src, dst, c, valid=valid)
    nv, ne = workset_sizes(g, bk.keep)
    return g, bk, jnp.int32(0), nv, ne


@jax.jit
def _slide_phase_a(state, drop, src, dst, c, valid):
    bk = _slide_prologue(state, drop, src, dst, valid)
    g, n_removed = remove_edges(state.graph, drop)
    g = append_edges(g, state.edge_count - n_removed, src, dst, c, valid=valid)
    nv, ne = workset_sizes(g, bk.keep)
    return g, bk, n_removed, nv, ne


@partial(
    jax.jit,
    static_argnames=("eps", "max_rounds", "v_bucket", "e_bucket", "use_kernel",
                     "with_drops", "d_bucket"),
    donate_argnames=("state", "g"),
)
def _phase_b(
    state, g, bk, n_removed, src, dst, c, valid,
    eps: float = 0.1,
    max_rounds: int = 0,
    v_bucket: int = 0,
    e_bucket: int = 0,
    use_kernel: bool = False,
    with_drops: bool = True,
    d_bucket: int = 0,
):
    """Warm re-peel + state merge.  ``v_bucket/e_bucket = 0`` selects the
    full-buffer fallback; otherwise the bucketed workset path."""
    if v_bucket and e_bucket:
        res = bulk_peel_warm_workset(
            g, bk.keep, prior_best_g=bk.prior_g, eps=eps, max_rounds=max_rounds,
            v_bucket=v_bucket, e_bucket=e_bucket, use_kernel=use_kernel,
        )
    else:
        res = bulk_peel_warm(g, bk.keep, prior_best_g=bk.prior_g, eps=eps,
                             max_rounds=max_rounds, use_kernel=use_kernel)
    return _slide_epilogue(state, g, res, bk, n_removed, src, dst, c, valid,
                           with_drops=with_drops, d_bucket=d_bucket)


def _dispatch_phase_b(
    state, g, bk, n_removed, src, dst, c, valid,
    nv, ne, eps, max_rounds, use_kernel, min_bucket, with_drops=True,
) -> tuple[DeviceSpadeState, WorksetTickInfo]:
    n_cap, e_cap = state.graph.n_capacity, state.graph.e_capacity
    # the tick's only device->host sync: three scalars, one transfer
    nv_i, ne_i, nd_i = (int(x) for x in np.asarray(
        jnp.stack([nv, ne, n_removed])
    ))
    bv = select_bucket(nv_i, n_cap, floor=min_bucket)
    be = select_bucket(ne_i, e_cap, floor=min_bucket)
    if bv is None or be is None:  # suffix too large: full-buffer fallback
        bv = be = 0
    # nothing actually dropped (e.g. window still filling): statically skip
    # the w0 decrement — same program as an insert tick, no extra variant
    with_drops = with_drops and nd_i > 0
    # bucket the dropped-edge count too: the w0 decrement then scatters
    # O(dropped) updates instead of O(E_capacity) (None -> full scatter)
    bd = 0
    if with_drops:
        bd = select_bucket(nd_i, e_cap, floor=min_bucket) or 0
    new_state = _phase_b(
        state, g, bk, n_removed, src, dst, c, valid,
        eps=eps, max_rounds=max_rounds, v_bucket=bv, e_bucket=be,
        use_kernel=use_kernel, with_drops=with_drops, d_bucket=bd,
    )
    return new_state, WorksetTickInfo(nv_i, ne_i, bv, be, not (bv and be))


def insert_and_maintain_auto(
    state: DeviceSpadeState,
    src: jax.Array,
    dst: jax.Array,
    c: jax.Array,
    valid: jax.Array,
    eps: float = 0.1,
    max_rounds: int = 0,
    use_kernel: bool = False,
    min_bucket: int = 64,
) -> tuple[DeviceSpadeState, WorksetTickInfo]:
    """:func:`insert_and_maintain` through the workset engine.

    Two device programs + one scalar sync per tick; bit-identical to the
    fused path on integer weights (workset or fallback alike).
    """
    g, bk, n_removed, nv, ne = _insert_phase_a(state, src, dst, c, valid)
    return _dispatch_phase_b(state, g, bk, n_removed, src, dst, c, valid,
                             nv, ne, eps, max_rounds, use_kernel, min_bucket,
                             with_drops=False)


def slide_and_maintain_auto(
    state: DeviceSpadeState,
    drop: jax.Array,
    src: jax.Array,
    dst: jax.Array,
    c: jax.Array,
    valid: jax.Array,
    eps: float = 0.1,
    max_rounds: int = 0,
    use_kernel: bool = False,
    min_bucket: int = 64,
) -> tuple[DeviceSpadeState, WorksetTickInfo]:
    """:func:`slide_and_maintain` through the workset engine (also covers
    pure deletion: pass an all-False ``valid``)."""
    g, bk, n_removed, nv, ne = _slide_phase_a(state, drop, src, dst, c, valid)
    return _dispatch_phase_b(state, g, bk, n_removed, src, dst, c, valid,
                             nv, ne, eps, max_rounds, use_kernel, min_bucket)


# ---------------------------------------------------------------------------
# predictive dispatch: pick buckets from the PREVIOUS tick's counts, check
# the fit on device, and fetch this tick's counts only after phase B is
# already in flight — no blocking device->host sync in the serving loop
# ---------------------------------------------------------------------------


class BucketPredictor:
    """Host-side predictive workset-bucket selector.

    The synced dispatcher (:func:`insert/slide_and_maintain_auto`) blocks
    on this tick's suffix counts before it can pick buckets and dispatch
    phase B — the serving loop's only blocking device->host transfer.
    The predictor removes it: buckets come from the running max of the
    last ``history`` ticks' counts, phase B dispatches immediately with a
    device-side fit check (:func:`repro.core.peel.bulk_peel_warm_checked`),
    and the actual counts are drained *after* dispatch, off the critical
    path, to feed the next prediction.  A bucket miss rides the in-program
    full-buffer fallback — the synced-scalar semantics, selected on device
    instead of on host — so prediction can cost a slow tick but never a
    wrong one.

    One predictor per served stream; ``e_capacity`` is the *per-shard*
    local capacity under a mesh (the sharded engine buckets per-shard
    counts; see ``sharded_workset_sizes``).
    """

    def __init__(
        self,
        n_capacity: int,
        e_capacity: int,
        min_bucket: int = 64,
        history: int = 4,
    ):
        self.n_capacity = int(n_capacity)
        self.e_capacity = int(e_capacity)
        self.min_bucket = int(min_bucket)
        self.history = max(int(history), 1)
        self._nv: list[int] = []
        self._ne: list[int] = []

    def predict(self) -> tuple[int, int] | None:
        """``None`` before any observation (callers take the synced path);
        ``(0, 0)`` when the recent suffix outgrew the bucket ladder (direct
        full-buffer dispatch, no check needed); else ``(v_bucket,
        e_bucket)`` for the checked dispatch."""
        if not self._nv:
            return None
        bv = select_bucket(max(self._nv), self.n_capacity, floor=self.min_bucket)
        be = select_bucket(max(self._ne), self.e_capacity, floor=self.min_bucket)
        if bv is None or be is None:
            return (0, 0)
        return (bv, be)

    def observe(self, nv: int, ne: int) -> None:
        self._nv = (self._nv + [int(nv)])[-self.history:]
        self._ne = (self._ne + [int(ne)])[-self.history:]


@partial(
    jax.jit,
    static_argnames=("eps", "max_rounds", "v_bucket", "e_bucket", "use_kernel",
                     "with_drops", "d_bucket"),
    donate_argnames=("state", "g"),
)
def _phase_b_checked(
    state, g, bk, n_removed, nv, ne, src, dst, c, valid,
    eps: float = 0.1,
    max_rounds: int = 0,
    v_bucket: int = 0,
    e_bucket: int = 0,
    use_kernel: bool = False,
    with_drops: bool = True,
    d_bucket: int = 0,
):
    """Phase B with predicted buckets: the workset/full-buffer choice moves
    onto the device (``lax.cond`` on the actual counts), so dispatch needs
    no host-resident count."""
    res, fits = bulk_peel_warm_checked(
        g, bk.keep, bk.prior_g, nv, ne, eps=eps, max_rounds=max_rounds,
        v_bucket=v_bucket, e_bucket=e_bucket, use_kernel=use_kernel,
    )
    return _slide_epilogue(state, g, res, bk, n_removed, src, dst, c, valid,
                           with_drops=with_drops, d_bucket=d_bucket), fits


def _predictive_dispatch_core(
    state, nv, ne, predictor: BucketPredictor, with_drops, n_dropped,
    *, synced, checked, full,
) -> tuple[DeviceSpadeState, WorksetTickInfo]:
    """Predictor-driven phase-B dispatch, shared by the single-device and
    mesh-sharded engines (they differ only in the three phase-B callables:
    ``synced(with_drops)``, ``checked(bv, be, wd, bd)``,
    ``full(wd, bd)``).

    Counts are fetched only *after* dispatch.  ``n_dropped`` is the host's
    (upper bound on the) number of live edges in the drop mask — the
    windowed service knows it exactly from its ring bookkeeping, which
    keeps the ``d_bucket`` compaction static without a sync; ``None``
    falls back to the full-width w0 decrement scatter."""
    pred = predictor.predict()
    if pred is None:
        # no history yet: classic synced-scalar dispatch seeds the predictor
        new_state, info = synced(with_drops)
        predictor.observe(info.n_suffix_vertices, info.n_suffix_edges)
        return new_state, info

    wd = with_drops and n_dropped != 0
    bd = 0
    if wd and n_dropped is not None:
        bd = select_bucket(n_dropped, state.graph.e_capacity,
                           floor=predictor.min_bucket) or 0
    bv, be = pred
    if bv and be:
        new_state, _fits = checked(bv, be, wd, bd)
    else:  # recent suffixes outgrew the ladder: full-buffer, no check
        new_state = full(wd, bd)
    # drained AFTER dispatch: the transfer overlaps phase B instead of
    # gating it — feeds the next prediction and the telemetry only
    nv_i, ne_i = (int(x) for x in np.asarray(jnp.stack([nv, ne])))
    predictor.observe(nv_i, ne_i)
    hit = bool(bv and be) and nv_i <= bv and ne_i <= be
    return new_state, WorksetTickInfo(
        nv_i, ne_i,
        v_bucket=bv if hit else 0,
        e_bucket=be if hit else 0,
        fallback=not hit,
        predicted=True,
        miss=bool(bv and be) and not hit,
    )


def _predictive_dispatch(
    state, g, bk, n_removed, src, dst, c, valid, nv, ne,
    predictor: BucketPredictor, eps, max_rounds, use_kernel,
    with_drops=True, n_dropped=None,
) -> tuple[DeviceSpadeState, WorksetTickInfo]:
    """Single-device binding of :func:`_predictive_dispatch_core`."""
    return _predictive_dispatch_core(
        state, nv, ne, predictor, with_drops, n_dropped,
        synced=lambda wd: _dispatch_phase_b(
            state, g, bk, n_removed, src, dst, c, valid, nv, ne,
            eps, max_rounds, use_kernel, predictor.min_bucket, with_drops=wd,
        ),
        checked=lambda bv, be, wd, bd: _phase_b_checked(
            state, g, bk, n_removed, nv, ne, src, dst, c, valid,
            eps=eps, max_rounds=max_rounds, v_bucket=bv, e_bucket=be,
            use_kernel=use_kernel, with_drops=wd, d_bucket=bd,
        ),
        full=lambda wd, bd: _phase_b(
            state, g, bk, n_removed, src, dst, c, valid,
            eps=eps, max_rounds=max_rounds, v_bucket=0, e_bucket=0,
            use_kernel=use_kernel, with_drops=wd, d_bucket=bd,
        ),
    )


def insert_and_maintain_predictive(
    state: DeviceSpadeState,
    src: jax.Array,
    dst: jax.Array,
    c: jax.Array,
    valid: jax.Array,
    predictor: BucketPredictor,
    eps: float = 0.1,
    max_rounds: int = 0,
    use_kernel: bool = False,
) -> tuple[DeviceSpadeState, WorksetTickInfo]:
    """:func:`insert_and_maintain_auto` without the blocking count sync:
    buckets are predicted from ``predictor``'s history and checked on
    device.  Bit-identical results to the synced/fused paths on integer
    weights (bucket choice never changes the math, only the cost)."""
    g, bk, n_removed, nv, ne = _insert_phase_a(state, src, dst, c, valid)
    return _predictive_dispatch(state, g, bk, n_removed, src, dst, c, valid,
                                nv, ne, predictor, eps, max_rounds, use_kernel,
                                with_drops=False, n_dropped=0)


def slide_and_maintain_predictive(
    state: DeviceSpadeState,
    drop: jax.Array,
    src: jax.Array,
    dst: jax.Array,
    c: jax.Array,
    valid: jax.Array,
    predictor: BucketPredictor,
    n_dropped: int | None = None,
    eps: float = 0.1,
    max_rounds: int = 0,
    use_kernel: bool = False,
) -> tuple[DeviceSpadeState, WorksetTickInfo]:
    """:func:`slide_and_maintain_auto` without the blocking count sync.

    ``n_dropped``: host-known upper bound on the live edges in ``drop``
    (the windowed service's ring count is exact); ``None`` keeps the
    full-width w0 decrement."""
    g, bk, n_removed, nv, ne = _slide_phase_a(state, drop, src, dst, c, valid)
    return _predictive_dispatch(state, g, bk, n_removed, src, dst, c, valid,
                                nv, ne, predictor, eps, max_rounds, use_kernel,
                                n_dropped=n_dropped)


@partial(jax.jit, static_argnames=("eps",))
def full_refresh(state: DeviceSpadeState, eps: float = 0.1) -> DeviceSpadeState:
    """Periodic from-scratch bulk peel (compaction / drift control)."""
    res = bulk_peel(state.graph, eps=eps)
    return DeviceSpadeState(
        graph=state.graph,
        level=res.level,
        best_g=res.best_g,
        community=res.community_mask() & state.graph.vertex_mask,
        edge_count=state.edge_count,
        w0=state.graph.peel_weights(),
    )


def admit_vertices(state: DeviceSpadeState, ids: jax.Array, a: jax.Array) -> DeviceSpadeState:
    """Activate new vertex ids (host-orchestrated; ids within capacity)."""
    g = state.graph
    vm = g.vertex_mask.at[ids].set(True, mode="drop")
    av = g.a.at[ids].set(a.astype(jnp.float32), mode="drop")
    return dataclasses.replace(
        state,
        graph=dataclasses.replace(g, vertex_mask=vm, a=av),
        level=state.level.at[ids].set(_LEVEL_NEW, mode="drop"),
        w0=state.w0.at[ids].set(a.astype(jnp.float32), mode="drop"),
    )
