"""Device-plane incremental maintenance (the paper's §4, TPU-native).

The host oracle reorders an explicit peeling sequence with a pending heap;
on TPU the same *affected-area* idea becomes a **warm suffix re-peel**:

1. Each vertex carries the ``level`` (bulk-peel round) at which it was
   peeled during the last maintenance pass.  The set
   ``{u : level[u] >= r}`` is exactly the active set at the start of round
   ``r`` (nested family — the vectorized analogue of the peel sequence).
2. An inserted batch only raises the weights of its endpoints (Lemma 4.1's
   vectorized form); with ``r0 = min_{endpoints} level``, every set before
   round ``r0`` is untouched, so maintenance re-peels only
   ``keep = level >= r0`` with weights/f recovered w.r.t. that suffix.
3. Thresholds inside the warm re-peel are computed on the *current*
   restricted set, so each round remains a valid generalized peeling step
   and the global ``2(1+eps)`` guarantee is preserved (proof sketch in
   DESIGN.md §2); the maintained best density never regresses because
   insertions only increase ``f`` of any set containing the endpoints.

New vertices are admitted with ``level = INT32_MAX`` (always inside the
re-peeled suffix without dragging ``r0`` down).
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.peel import PeelResultDevice, bulk_peel, bulk_peel_warm
from repro.graphstore.structs import DeviceGraph, append_edges

__all__ = [
    "DeviceSpadeState",
    "init_state",
    "insert_and_maintain",
    "full_refresh",
    "benign_mask",
]

_LEVEL_NEW = jnp.int32(2**31 - 1)


@partial(
    jax.tree_util.register_dataclass,
    data_fields=["graph", "level", "best_g", "community", "edge_count", "w0"],
    meta_fields=[],
)
@dataclasses.dataclass(frozen=True)
class DeviceSpadeState:
    """Evolving-graph fraud-detection state (pure pytree, donate-friendly).

    ``w0[u]`` mirrors the full-graph peeling weight ``w_u(S_0)`` for the
    O(1) benign/urgent test (Def 4.1).
    """

    graph: DeviceGraph
    level: jax.Array  # int32 [V_cap] peel round per vertex
    best_g: jax.Array  # float32 scalar — maintained best density
    community: jax.Array  # bool [V_cap] — maintained S^P
    edge_count: jax.Array  # int32 scalar — next free edge slot
    w0: jax.Array  # float32 [V_cap]


def init_state(g: DeviceGraph, eps: float = 0.1) -> DeviceSpadeState:
    """Static bulk peel (Algorithm 1, bulk form) to seed the state."""
    res = bulk_peel(g, eps=eps)
    return DeviceSpadeState(
        graph=g,
        level=res.level,
        best_g=res.best_g,
        community=res.community_mask() & g.vertex_mask,
        edge_count=jnp.sum(g.edge_mask).astype(jnp.int32),
        w0=g.peel_weights(),
    )


def benign_mask(state: DeviceSpadeState, src, dst, c) -> jax.Array:
    """Vectorized Def 4.1: an edge is benign iff *both* endpoint tests fail
    the urgency condition ``w_u(S_0) + c >= g(S^P)``."""
    urgent = (state.w0[src] + c >= state.best_g) | (state.w0[dst] + c >= state.best_g)
    return ~urgent


@partial(jax.jit, static_argnames=("eps", "max_rounds", "unroll"),
         donate_argnames=("state",))
def insert_and_maintain(
    state: DeviceSpadeState,
    src: jax.Array,
    dst: jax.Array,
    c: jax.Array,
    valid: jax.Array,
    eps: float = 0.1,
    max_rounds: int = 0,
    unroll: bool = False,
) -> DeviceSpadeState:
    """Insert an edge batch and maintain the community incrementally.

    ``src/dst/c`` are fixed-size batch arrays with a ``valid`` mask
    (streaming ticks pad to the batch size).  One fused device program:
    append -> affected-suffix recovery -> warm bulk re-peel -> state merge.
    """
    g = append_edges(state.graph, state.edge_count, src, dst, c, valid=valid)
    n_new = jnp.sum(valid).astype(jnp.int32)

    # affected suffix start: min endpoint level over the valid batch
    lvl_src = jnp.where(valid, state.level[src], _LEVEL_NEW)
    lvl_dst = jnp.where(valid, state.level[dst], _LEVEL_NEW)
    r0 = jnp.minimum(jnp.min(lvl_src), jnp.min(lvl_dst))
    r0 = jnp.where(n_new > 0, r0, _LEVEL_NEW)  # empty batch: re-peel nothing
    r0 = jnp.minimum(r0, jnp.int32(2**30))  # overflow-safe rebasing
    keep = state.level >= r0

    res = bulk_peel_warm(g, keep, prior_best_g=state.best_g, eps=eps,
                         max_rounds=max_rounds, unroll=unroll)

    # rebase suffix levels above the untouched prefix; vertices still active
    # at a max_rounds cutoff conceptually peel in the final round
    suffix_level = jnp.where(res.level >= 0, res.level, res.n_rounds)
    new_level = jnp.where(keep, r0 + suffix_level, state.level)
    improved = res.best_g > state.best_g
    new_comm = jnp.where(
        improved,
        (res.level >= res.best_level) & keep & g.vertex_mask,
        state.community,
    )
    w0 = state.w0
    cv = jnp.where(valid, c.astype(jnp.float32), 0.0)
    w0 = w0.at[src].add(cv, mode="drop")
    w0 = w0.at[dst].add(cv, mode="drop")
    return DeviceSpadeState(
        graph=g,
        level=new_level,
        best_g=jnp.maximum(res.best_g, state.best_g),
        community=new_comm,
        edge_count=state.edge_count + n_new,
        w0=w0,
    )


@partial(jax.jit, static_argnames=("eps",))
def full_refresh(state: DeviceSpadeState, eps: float = 0.1) -> DeviceSpadeState:
    """Periodic from-scratch bulk peel (compaction / drift control)."""
    res = bulk_peel(state.graph, eps=eps)
    return DeviceSpadeState(
        graph=state.graph,
        level=res.level,
        best_g=res.best_g,
        community=res.community_mask() & state.graph.vertex_mask,
        edge_count=state.edge_count,
        w0=state.graph.peel_weights(),
    )


def admit_vertices(state: DeviceSpadeState, ids: jax.Array, a: jax.Array) -> DeviceSpadeState:
    """Activate new vertex ids (host-orchestrated; ids within capacity)."""
    g = state.graph
    vm = g.vertex_mask.at[ids].set(True, mode="drop")
    av = g.a.at[ids].set(a.astype(jnp.float32), mode="drop")
    return dataclasses.replace(
        state,
        graph=dataclasses.replace(g, vertex_mask=vm, a=av),
        level=state.level.at[ids].set(_LEVEL_NEW, mode="drop"),
        w0=state.w0.at[ids].set(a.astype(jnp.float32), mode="drop"),
    )
