"""Device-plane incremental maintenance (the paper's §4, TPU-native).

The host oracle reorders an explicit peeling sequence with a pending heap;
on TPU the same *affected-area* idea becomes a **warm suffix re-peel**:

1. Each vertex carries the ``level`` (bulk-peel round) at which it was
   peeled during the last maintenance pass.  The set
   ``{u : level[u] >= r}`` is exactly the active set at the start of round
   ``r`` (nested family — the vectorized analogue of the peel sequence).
2. An inserted batch only raises the weights of its endpoints (Lemma 4.1's
   vectorized form); with ``r0 = min_{endpoints} level``, every set before
   round ``r0`` is untouched, so maintenance re-peels only
   ``keep = level >= r0`` with weights/f recovered w.r.t. that suffix.
3. Thresholds inside the warm re-peel are computed on the *current*
   restricted set, so each round remains a valid generalized peeling step
   and the global ``2(1+eps)`` guarantee is preserved (proof sketch in
   DESIGN.md §2); the maintained best density never regresses because
   insertions only increase ``f`` of any set containing the endpoints.

New vertices are admitted with ``level = INT32_MAX`` (always inside the
re-peeled suffix without dragging ``r0`` down).
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.peel import PeelResultDevice, bulk_peel, bulk_peel_warm
from repro.graphstore.structs import DeviceGraph, append_edges, remove_edges

__all__ = [
    "DeviceSpadeState",
    "init_state",
    "insert_and_maintain",
    "delete_and_maintain",
    "slide_and_maintain",
    "full_refresh",
    "benign_mask",
]

_LEVEL_NEW = jnp.int32(2**31 - 1)


@partial(
    jax.tree_util.register_dataclass,
    data_fields=["graph", "level", "best_g", "community", "edge_count", "w0"],
    meta_fields=[],
)
@dataclasses.dataclass(frozen=True)
class DeviceSpadeState:
    """Evolving-graph fraud-detection state (pure pytree, donate-friendly).

    ``w0[u]`` mirrors the full-graph peeling weight ``w_u(S_0)`` for the
    O(1) benign/urgent test (Def 4.1).
    """

    graph: DeviceGraph
    level: jax.Array  # int32 [V_cap] peel round per vertex
    best_g: jax.Array  # float32 scalar — maintained best density
    community: jax.Array  # bool [V_cap] — maintained S^P
    edge_count: jax.Array  # int32 scalar — next free edge slot
    w0: jax.Array  # float32 [V_cap]


def init_state(g: DeviceGraph, eps: float = 0.1) -> DeviceSpadeState:
    """Static bulk peel (Algorithm 1, bulk form) to seed the state."""
    res = bulk_peel(g, eps=eps)
    return DeviceSpadeState(
        graph=g,
        level=res.level,
        best_g=res.best_g,
        community=res.community_mask() & g.vertex_mask,
        edge_count=jnp.sum(g.edge_mask).astype(jnp.int32),
        w0=g.peel_weights(),
    )


def benign_mask(state: DeviceSpadeState, src, dst, c) -> jax.Array:
    """Vectorized Def 4.1: an edge is benign iff *both* endpoint tests fail
    the urgency condition ``w_u(S_0) + c >= g(S^P)``."""
    urgent = (state.w0[src] + c >= state.best_g) | (state.w0[dst] + c >= state.best_g)
    return ~urgent


@partial(jax.jit, static_argnames=("eps", "max_rounds", "unroll"),
         donate_argnames=("state",))
def insert_and_maintain(
    state: DeviceSpadeState,
    src: jax.Array,
    dst: jax.Array,
    c: jax.Array,
    valid: jax.Array,
    eps: float = 0.1,
    max_rounds: int = 0,
    unroll: bool = False,
) -> DeviceSpadeState:
    """Insert an edge batch and maintain the community incrementally.

    ``src/dst/c`` are fixed-size batch arrays with a ``valid`` mask
    (streaming ticks pad to the batch size).  One fused device program:
    append -> affected-suffix recovery -> warm bulk re-peel -> state merge.
    """
    g = append_edges(state.graph, state.edge_count, src, dst, c, valid=valid)
    n_new = jnp.sum(valid).astype(jnp.int32)

    # affected suffix start: min endpoint level over the valid batch
    lvl_src = jnp.where(valid, state.level[src], _LEVEL_NEW)
    lvl_dst = jnp.where(valid, state.level[dst], _LEVEL_NEW)
    r0 = jnp.minimum(jnp.min(lvl_src), jnp.min(lvl_dst))
    r0 = jnp.where(n_new > 0, r0, _LEVEL_NEW)  # empty batch: re-peel nothing
    r0 = jnp.minimum(r0, jnp.int32(2**30))  # overflow-safe rebasing
    keep = state.level >= r0

    res = bulk_peel_warm(g, keep, prior_best_g=state.best_g, eps=eps,
                         max_rounds=max_rounds, unroll=unroll)

    # rebase suffix levels above the untouched prefix; vertices still active
    # at a max_rounds cutoff conceptually peel in the final round
    suffix_level = jnp.where(res.level >= 0, res.level, res.n_rounds)
    new_level = jnp.where(keep, r0 + suffix_level, state.level)
    improved = res.best_g > state.best_g
    new_comm = jnp.where(
        improved,
        (res.level >= res.best_level) & keep & g.vertex_mask,
        state.community,
    )
    w0 = state.w0
    cv = jnp.where(valid, c.astype(jnp.float32), 0.0)
    w0 = w0.at[src].add(cv, mode="drop")
    w0 = w0.at[dst].add(cv, mode="drop")
    return DeviceSpadeState(
        graph=g,
        level=new_level,
        best_g=jnp.maximum(res.best_g, state.best_g),
        community=new_comm,
        edge_count=state.edge_count + n_new,
        w0=w0,
    )


class _SlideBookkeeping(NamedTuple):
    """Replicated pre-re-peel bookkeeping shared by the single-device and
    the mesh-sharded window-slide paths (one definition so the two engines
    cannot drift — the same role ``compact_slots`` plays for appends)."""

    dropped: jax.Array  # [E] live slots being expired
    cd: jax.Array  # [E] expired suspiciousness (0 elsewhere)
    n_new: jax.Array
    r0: jax.Array
    keep: jax.Array
    prior_g: jax.Array


def _slide_prologue(
    state: DeviceSpadeState, drop: jax.Array, src, dst, valid
) -> _SlideBookkeeping:
    g0 = state.graph
    dropped = drop & g0.edge_mask
    n_del = jnp.sum(dropped).astype(jnp.int32)
    cd = jnp.where(dropped, g0.c, 0.0)
    n_new = jnp.sum(valid).astype(jnp.int32)

    # affected suffix start: min endpoint level over dropped AND inserted
    # edges (both endpoint sets sit inside the re-peeled suffix)
    lvl = jnp.minimum(
        jnp.min(jnp.where(dropped, state.level[g0.src], _LEVEL_NEW)),
        jnp.min(jnp.where(dropped, state.level[g0.dst], _LEVEL_NEW)),
    )
    lvl = jnp.minimum(lvl, jnp.min(jnp.where(valid, state.level[src], _LEVEL_NEW)))
    lvl = jnp.minimum(lvl, jnp.min(jnp.where(valid, state.level[dst], _LEVEL_NEW)))
    r0 = jnp.where((n_del > 0) | (n_new > 0), lvl, _LEVEL_NEW)
    r0 = jnp.minimum(r0, jnp.int32(2**30))

    # exact density of the old community in the post-deletion graph: it
    # loses the dropped mass with both endpoints inside S^P (stale-low if
    # best_g was already conservative — only ever under-reports, never
    # hides fraud); re-seeds the best tracker since deletion may regress it
    in_comm = state.community[g0.src] & state.community[g0.dst]
    comm_loss = jnp.sum(jnp.where(dropped & in_comm, g0.c, 0.0))
    n_comm = jnp.sum(state.community).astype(jnp.float32)
    prior_g = jnp.where(
        n_comm > 0, state.best_g - comm_loss / jnp.maximum(n_comm, 1.0),
        -jnp.float32(jnp.inf),
    )
    return _SlideBookkeeping(
        dropped=dropped, cd=cd, n_new=n_new, r0=r0,
        keep=state.level >= r0, prior_g=prior_g,
    )


def _slide_epilogue(
    state: DeviceSpadeState,
    g: DeviceGraph,
    res: PeelResultDevice,
    bk: _SlideBookkeeping,
    n_removed: jax.Array,
    src, dst, c, valid,
) -> DeviceSpadeState:
    """Merge a warm re-peel back into the state (level rebase, community
    update, exact w0 decrement/increment, edge-counter move)."""
    g0 = state.graph
    suffix_level = jnp.where(res.level >= 0, res.level, res.n_rounds)
    new_level = jnp.where(bk.keep, bk.r0 + suffix_level, state.level)
    improved = res.best_g > bk.prior_g
    new_comm = jnp.where(
        improved,
        (res.level >= res.best_level) & bk.keep & g.vertex_mask,
        state.community,
    )
    # exact on integer weights; padding lanes carry cd = 0 / cv = 0
    w0 = state.w0.at[g0.src].add(-bk.cd, mode="drop")
    w0 = w0.at[g0.dst].add(-bk.cd, mode="drop")
    cv = jnp.where(valid, c.astype(jnp.float32), 0.0)
    w0 = w0.at[src].add(cv, mode="drop")
    w0 = w0.at[dst].add(cv, mode="drop")
    return DeviceSpadeState(
        graph=g,
        level=new_level,
        best_g=jnp.maximum(res.best_g, bk.prior_g),
        community=new_comm,
        edge_count=state.edge_count - n_removed + bk.n_new,
        w0=w0,
    )


def delete_and_maintain(
    state: DeviceSpadeState,
    drop: jax.Array,
    eps: float = 0.1,
    max_rounds: int = 0,
    unroll: bool = False,
) -> DeviceSpadeState:
    """Delete the edges in slot mask ``drop`` and maintain incrementally.

    The deletion mirror of :func:`insert_and_maintain` (paper Appendix C.1,
    vectorized — DESIGN.md §6): deleted edges only *lower* the weights of
    their endpoints, and with ``r0 = min_{endpoints} level`` both endpoints
    of every dropped edge sit inside the suffix ``level >= r0``, so no
    prefix vertex's peel-time weight changes and only the suffix is
    re-peeled.  Unlike insertion the maintained best density may legally
    *regress*: the tracker is re-seeded with the exact density of the
    previous community in the post-deletion graph (its stored value minus
    the dropped mass with both endpoints inside it) rather than the stale
    pre-deletion value.  ``remove_edges`` compacts the surviving slots to
    the buffer prefix, so the edge counter simply shrinks by the number of
    live edges dropped.

    Exactly a window slide with an empty insert batch (the shared jitted
    program handles both).
    """
    z = jnp.zeros(1, jnp.int32)
    return slide_and_maintain(
        state, drop, z, z, z.astype(jnp.float32), jnp.zeros(1, bool),
        eps=eps, max_rounds=max_rounds, unroll=unroll,
    )


@partial(jax.jit, static_argnames=("eps", "max_rounds", "unroll"),
         donate_argnames=("state",))
def slide_and_maintain(
    state: DeviceSpadeState,
    drop: jax.Array,
    src: jax.Array,
    dst: jax.Array,
    c: jax.Array,
    valid: jax.Array,
    eps: float = 0.1,
    max_rounds: int = 0,
    unroll: bool = False,
) -> DeviceSpadeState:
    """One fused sliding-window tick: expire ``drop``, insert the batch,
    re-peel **once** (paper Appendix C.3, vectorized).

    Composing :func:`delete_and_maintain` + :func:`insert_and_maintain`
    would re-peel the affected suffix twice per tick; here ``r0`` is the
    minimum endpoint level over dropped *and* inserted edges, so a single
    warm re-peel covers both updates — the steady-state serving loop does
    one device program per tick.  Bookkeeping composes the two paths:
    ``w0`` is decremented by dropped mass and incremented by inserted
    mass, the best-density tracker is re-seeded with the old community's
    exact post-deletion density (DESIGN.md §6), and the edge counter
    shrinks by the dropped count and grows by the inserted count.
    """
    bk = _slide_prologue(state, drop, src, dst, valid)
    g, n_removed = remove_edges(state.graph, drop)
    g = append_edges(g, state.edge_count - n_removed, src, dst, c, valid=valid)
    res = bulk_peel_warm(g, bk.keep, prior_best_g=bk.prior_g, eps=eps,
                         max_rounds=max_rounds, unroll=unroll)
    return _slide_epilogue(state, g, res, bk, n_removed, src, dst, c, valid)


@partial(jax.jit, static_argnames=("eps",))
def full_refresh(state: DeviceSpadeState, eps: float = 0.1) -> DeviceSpadeState:
    """Periodic from-scratch bulk peel (compaction / drift control)."""
    res = bulk_peel(state.graph, eps=eps)
    return DeviceSpadeState(
        graph=state.graph,
        level=res.level,
        best_g=res.best_g,
        community=res.community_mask() & state.graph.vertex_mask,
        edge_count=state.edge_count,
        w0=state.graph.peel_weights(),
    )


def admit_vertices(state: DeviceSpadeState, ids: jax.Array, a: jax.Array) -> DeviceSpadeState:
    """Activate new vertex ids (host-orchestrated; ids within capacity)."""
    g = state.graph
    vm = g.vertex_mask.at[ids].set(True, mode="drop")
    av = g.a.at[ids].set(a.astype(jnp.float32), mode="drop")
    return dataclasses.replace(
        state,
        graph=dataclasses.replace(g, vertex_mask=vm, a=av),
        level=state.level.at[ids].set(_LEVEL_NEW, mode="drop"),
        w0=state.w0.at[ids].set(a.astype(jnp.float32), mode="drop"),
    )
