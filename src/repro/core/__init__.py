"""Spade core: peeling algorithms, incremental maintenance, fraud semantics.

Host plane (exact oracle): :mod:`repro.core.reference`, :mod:`repro.core.spade`.
Device plane (JAX/TPU):    :mod:`repro.core.peel`, :mod:`repro.core.incremental`.
Semantics API:             :mod:`repro.core.semantics` (SuspSemantics — one
                           VSusp/ESusp definition compiled into every engine;
                           DG / DW / FD as registered instances) with the
                           host projection in :mod:`repro.core.metrics`.
"""

from .metrics import DG, DW, FD, DensityMetric, make_fd, make_metric
from .semantics import SuspSemantics, available, register, resolve
from .reference import (
    AdjGraph,
    PeelState,
    ReorderStats,
    delete_edge,
    density_sequence,
    detect,
    enumerate_communities,
    insert_edges,
    peeling_weights_full,
    recompute,
    static_peel,
)
from .spade import InsertResult, Spade

__all__ = [
    "AdjGraph",
    "PeelState",
    "ReorderStats",
    "DensityMetric",
    "SuspSemantics",
    "register",
    "resolve",
    "available",
    "DG",
    "DW",
    "FD",
    "make_fd",
    "make_metric",
    "static_peel",
    "insert_edges",
    "delete_edge",
    "enumerate_communities",
    "detect",
    "density_sequence",
    "peeling_weights_full",
    "recompute",
    "Spade",
    "InsertResult",
]
