"""Device-plane suspiciousness weighting (DG/DW/FD parity with
:mod:`repro.core.metrics`, vectorized).

The host plane evaluates ``esusp`` per edge at arrival; the device plane
weights whole batches at once.  FD's column weighting needs the live
destination in-degree — maintained as an int32 vector updated with the
same scatter that appends the edges.

Quantization boundary: :func:`seed_base_weights` snaps the base graph to
the host funnel's dyadic 2^-30 grid (float64 math on host), but the
*streamed* tick weights below stay raw float32 — the exact float64 snap
is not reproducible on device without x64, so host-vs-device weight
parity on streamed edges holds to f32 ulps (and exactly on integer
weights, which is what the differential harnesses pin).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.metrics import _QUANTUM, quantize_susp_array

__all__ = ["dg_weights", "dw_weights", "fd_weights", "fd_batch_weights",
           "seed_base_weights"]


def seed_base_weights(
    metric: str,
    src: np.ndarray,
    dst: np.ndarray,
    amt: np.ndarray,
    n: int,
    C: float = 5.0,
) -> tuple[np.ndarray, np.ndarray]:
    """Base-graph edge suspiciousness for a device-plane service (host side).

    One definition of the FD/DW/DG base-weight seeding shared by every
    service plane (single-device and mesh-sharded alike), snapped to the
    same dyadic 2^-30 grid as the host metric funnel
    (:func:`repro.core.metrics.quantize_susp`) so the two planes' stored
    weights cannot drift by an ulp and weight ties stay exact ties.

    FD uses the *loaded-graph* destination in-degree (the device plane
    seeds the whole base graph at once; per-arrival degrees start with the
    incremental stream, via :func:`fd_batch_weights`).

    Returns ``(base_w float32 [m], in_deg int64 [n])`` — the in-degree
    vector doubles as the FD degree state the streaming ticks continue
    from.
    """
    src = np.asarray(src)
    dst = np.asarray(dst)
    in_deg = np.zeros(n, np.int64)
    np.add.at(in_deg, dst, 1)
    if metric == "DG":
        w = np.ones(src.shape[0], np.float64)
    elif metric == "DW":
        w = np.maximum(np.asarray(amt, np.float64), 1e-12)
    elif metric == "FD":
        w = 1.0 / np.log(in_deg[dst] + C)
    else:
        raise KeyError(f"unknown metric {metric!r}; choose from DG/DW/FD")
    w = np.maximum(quantize_susp_array(w), _QUANTUM)  # positive through the snap
    return w.astype(np.float32), in_deg


def dg_weights(amounts: jax.Array) -> jax.Array:
    """DG: unweighted — every transaction counts 1."""
    return jnp.ones_like(amounts, dtype=jnp.float32)


def dw_weights(amounts: jax.Array) -> jax.Array:
    """DW: transaction amount (clamped positive)."""
    return jnp.maximum(amounts.astype(jnp.float32), 1e-12)


def fd_weights(in_deg_dst: jax.Array, C: float = 5.0) -> jax.Array:
    """FD column weighting 1/log(x + C) given destination in-degrees."""
    return 1.0 / jnp.log(in_deg_dst.astype(jnp.float32) + C)


def fd_batch_weights(
    in_deg: jax.Array, dst: jax.Array, valid: jax.Array, C: float = 5.0
) -> tuple[jax.Array, jax.Array]:
    """Weight a batch FD-style with *arrival-time* degrees (host parity:
    each edge sees the degree including earlier edges of the same batch).

    Returns (edge weights, updated in_deg vector).
    """
    ones = valid.astype(jnp.int32)
    # degree of dst at each edge's arrival = stored degree + # earlier batch
    # edges with the same dst (exclusive running count via segment trick)
    B = dst.shape[0]
    same = (dst[:, None] == dst[None, :]) & valid[None, :] & valid[:, None]
    earlier = jnp.tril(same, k=-1).sum(axis=1)
    deg_at_arrival = in_deg[dst] + earlier
    w = jnp.where(valid, 1.0 / jnp.log(deg_at_arrival.astype(jnp.float32) + C), 0.0)
    new_deg = in_deg.at[dst].add(ones, mode="drop")
    return w, new_deg
