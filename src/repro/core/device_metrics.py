"""DEPRECATED device-plane weighting helpers (legacy ``metric: str`` API).

The hardcoded DG/DW/FD trio that used to live here is gone: every weight
below now delegates to the registered :class:`repro.core.semantics.
SuspSemantics` instances, whose ``seed_base``/``batch_weights`` are the
one definition all four engines compile (see semantics.py for the
quantization boundary).  These wrappers exist only so legacy callers and
tests keep working; new code should use the semantics object directly
(``semantics=DW`` on :class:`repro.serve.SpadeService`, or
``sem.batch_weights(...)``).  Each call emits a
:class:`~repro._warnings.SpadeDeprecationWarning`.
"""

from __future__ import annotations

import warnings

import jax
import jax.numpy as jnp
import numpy as np

from repro._warnings import SpadeDeprecationWarning
from repro.core import semantics as _sem

__all__ = ["dg_weights", "dw_weights", "fd_weights", "fd_batch_weights",
           "seed_base_weights"]


def _warn(old: str, new: str) -> None:
    warnings.warn(
        f"{old} is deprecated; use {new} (repro.core.semantics)",
        SpadeDeprecationWarning,
        stacklevel=3,
    )


def seed_base_weights(
    metric: str,
    src: np.ndarray,
    dst: np.ndarray,
    amt: np.ndarray,
    n: int,
    C: float = 5.0,
) -> tuple[np.ndarray, np.ndarray]:
    """DEPRECATED: ``resolve(metric).seed_base(...)`` — the registry-backed
    batch-seeding rule (identical output, including the dyadic snap)."""
    _warn("seed_base_weights(metric=...)", "SuspSemantics.seed_base")
    if C != 5.0:
        raise ValueError("legacy shim supports only the paper's C = 5.0")
    return _sem.resolve(metric).seed_base(src, dst, amt, n)


def dg_weights(amounts: jax.Array) -> jax.Array:
    """DEPRECATED: DG semantics — every transaction counts 1."""
    _warn("dg_weights", "semantics.DG")
    return _sem.DG.esusp(jnp, None, None, amounts.astype(jnp.float32), None,
                         None)


def dw_weights(amounts: jax.Array) -> jax.Array:
    """DEPRECATED: DW semantics — transaction amount (clamped positive)."""
    _warn("dw_weights", "semantics.DW")
    return _sem.DW.esusp(jnp, None, None, amounts.astype(jnp.float32), None,
                         None)


def fd_weights(in_deg_dst: jax.Array, C: float = 5.0) -> jax.Array:
    """DEPRECATED: FD column weighting given destination in-degrees."""
    _warn("fd_weights", "semantics.FD")
    if C != 5.0:
        raise ValueError("legacy shim supports only the paper's C = 5.0")
    return _sem.FD.esusp(jnp, None, None, None, in_deg_dst, None)


def fd_batch_weights(
    in_deg: jax.Array, dst: jax.Array, valid: jax.Array, C: float = 5.0
) -> tuple[jax.Array, jax.Array]:
    """DEPRECATED: ``FD.batch_weights`` — arrival-time degree weighting of
    one batch (identical output)."""
    _warn("fd_batch_weights", "SuspSemantics.batch_weights")
    if C != 5.0:
        raise ValueError("legacy shim supports only the paper's C = 5.0")
    zeros = jnp.zeros(dst.shape[0], jnp.float32)
    w, new_deg = _sem.FD.batch_weights(in_deg, dst, dst, zeros, valid)
    return w, new_deg
