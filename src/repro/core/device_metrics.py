"""Device-plane suspiciousness weighting (DG/DW/FD parity with
:mod:`repro.core.metrics`, vectorized).

The host plane evaluates ``esusp`` per edge at arrival; the device plane
weights whole batches at once.  FD's column weighting needs the live
destination in-degree — maintained as an int32 vector updated with the
same scatter that appends the edges.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["dg_weights", "dw_weights", "fd_weights", "fd_batch_weights"]


def dg_weights(amounts: jax.Array) -> jax.Array:
    """DG: unweighted — every transaction counts 1."""
    return jnp.ones_like(amounts, dtype=jnp.float32)


def dw_weights(amounts: jax.Array) -> jax.Array:
    """DW: transaction amount (clamped positive)."""
    return jnp.maximum(amounts.astype(jnp.float32), 1e-12)


def fd_weights(in_deg_dst: jax.Array, C: float = 5.0) -> jax.Array:
    """FD column weighting 1/log(x + C) given destination in-degrees."""
    return 1.0 / jnp.log(in_deg_dst.astype(jnp.float32) + C)


def fd_batch_weights(
    in_deg: jax.Array, dst: jax.Array, valid: jax.Array, C: float = 5.0
) -> tuple[jax.Array, jax.Array]:
    """Weight a batch FD-style with *arrival-time* degrees (host parity:
    each edge sees the degree including earlier edges of the same batch).

    Returns (edge weights, updated in_deg vector).
    """
    ones = valid.astype(jnp.int32)
    # degree of dst at each edge's arrival = stored degree + # earlier batch
    # edges with the same dst (exclusive running count via segment trick)
    B = dst.shape[0]
    same = (dst[:, None] == dst[None, :]) & valid[None, :] & valid[:, None]
    earlier = jnp.tril(same, k=-1).sum(axis=1)
    deg_at_arrival = in_deg[dst] + earlier
    w = jnp.where(valid, 1.0 / jnp.log(deg_at_arrival.astype(jnp.float32) + C), 0.0)
    new_deg = in_deg.at[dst].add(ones, mode="drop")
    return w, new_deg
