"""The pluggable semantics plane: one ``SuspSemantics`` definition compiled
into every engine (paper §6's API promise, honored beyond the host oracle).

A fraud semantics is the paper's VSusp/ESusp pair.  Before this module the
pair existed only on the host plane (``core/metrics.DensityMetric``); the
device, mesh-sharded, and workset engines dispatched on a ``metric: str``
into three hardcoded weight functions, so a user-defined semantics could
never reach a fast path.  ``SuspSemantics`` closes that gap: the two hooks
are written once, against an array-module parameter ``xp``, and the same
definition is

* evaluated per edge in float64 by the host oracle (``xp = numpy``, via
  :meth:`SuspSemantics.host_metric` -> ``DensityMetric`` adapter),
* vectorized over the base graph at service start (``xp = numpy``,
  :meth:`SuspSemantics.seed_base` — the batch-seeding rule), and
* jit-compiled into the streaming tick of the single-device, mesh-sharded
  and workset engines (``xp = jax.numpy``,
  :meth:`SuspSemantics.batch_weights`).

Hook signatures (all vectorized; ``aux`` is the per-edge application
payload — the bundled services feed the transaction timestamp — or ``None``
when the plane has no aux channel):

* ``esusp(xp, src, dst, raw, in_deg_dst, aux) -> [E]``  edge suspiciousness
  (> 0), with ``raw`` the application payload (e.g. amount) and
  ``in_deg_dst`` the destination in-degree *at arrival time*.
* ``vsusp(xp, ids, in_deg, aux) -> [V]``  vertex prior (>= 0), or ``None``
  for the all-zero prior.

**Quantization boundary.**  Suspiciousness snaps to the dyadic ``2^-30``
grid *here*, at the protocol boundary — in the host funnel the adapter
produces and in the base-graph seeding — never inside a semantics
definition and never inside an engine.  Grid values below ``2^23`` sum
exactly in float64/float32 in any order, so host/device weight parity (and
id-stable tie-breaks) is a property of the API: any registered semantics
inherits it, DG/DW/FD and user-defined alike.  Streamed tick weights stay
raw float32 (the float64 snap is not reproducible on device without x64);
on integer-valued suspiciousness every plane is bit-identical, which is
what the differential harness pins (DESIGN.md §9).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable

import numpy as np

__all__ = [
    "SuspSemantics",
    "DG",
    "DW",
    "FD",
    "register",
    "resolve",
    "available",
    "quantize_susp",
    "quantize_susp_array",
]

# Dyadic grid (multiples of 2^-30).  Rationale (determinism contract,
# reference.py): the incremental reorder recovers peeling weights as
# Delta_old + edge terms while the from-scratch peel runs a running
# subtraction — different float64 summation orders.  Irrational semantics
# values (FD's 1/log) then drift by an ulp between the two runs and the
# (weight, id) tie-break resolves "equal" weights differently.  Grid values
# with magnitude below 2^23 sum *exactly* in float64 in any order, so ties
# are exact ties and the vertex-id tie-break is stable across incremental
# and scratch runs.  The 2^-30 (~1e-9 relative) snap is far below any
# fraud-semantics signal.
_QUANT_BITS = 30
_QUANTUM = math.ldexp(1.0, -_QUANT_BITS)


def quantize_susp(x: float) -> float:
    """Round a suspiciousness value to the shared dyadic grid."""
    return math.ldexp(round(math.ldexp(x, _QUANT_BITS)), -_QUANT_BITS)


def quantize_susp_array(x):
    """Vectorized :func:`quantize_susp` (numpy, float64 intermediate).

    ``np.rint`` rounds half-to-even exactly like the scalar ``round``, so
    host-plane per-edge quantization and device-plane batch seeding land
    on identical grid points — the single definition both planes share.
    """
    return np.ldexp(
        np.rint(np.ldexp(np.asarray(x, np.float64), _QUANT_BITS)), -_QUANT_BITS
    )


ESuspArrayFn = Callable[..., Any]  # (xp, src, dst, raw, in_deg_dst, aux) -> [E]
VSuspArrayFn = Callable[..., Any]  # (xp, ids, in_deg, aux) -> [V]


@dataclasses.dataclass(frozen=True)
class SuspSemantics:
    """A pluggable, engine-agnostic fraud-semantics definition.

    ``uses_degree`` declares that ``esusp`` reads ``in_deg_dst`` (FD-style
    column weighting): the streaming engines then maintain the arrival-time
    in-degree vector and resolve intra-batch arrival order; otherwise the
    (stale) stored degrees are passed and the update is elided from the
    tick program.  ``uses_aux`` declares that the hooks read ``aux``: the
    bundled services feed the transaction timestamp (base edges carry 0.0);
    planes without an aux channel (the host oracle's per-edge funnel) pass
    ``None`` — a semantics that *requires* aux is device-plane-only unless
    its hooks tolerate ``aux=None``.

    Instances are frozen and hashable by identity — safe to close over in
    jitted tick programs.
    """

    name: str
    esusp: ESuspArrayFn
    vsusp: VSuspArrayFn | None = None
    uses_degree: bool = False
    uses_aux: bool = False

    # -- the batch-seeding rule (host side, float64, snapped) ---------------

    def seed_base(
        self,
        src: np.ndarray,
        dst: np.ndarray,
        raw: np.ndarray,
        n: int,
        aux: np.ndarray | None = None,
    ) -> tuple[np.ndarray, np.ndarray]:
        """Base-graph edge suspiciousness for a device-plane service.

        One definition shared by every service plane (single-device,
        mesh-sharded, workset), snapped to the dyadic grid at this boundary
        so stored weights cannot drift by an ulp between planes and weight
        ties stay exact ties.

        Degree-using semantics see the *loaded-graph* destination in-degree
        (the device plane seeds the whole base graph at once; per-arrival
        degrees start with the incremental stream via
        :meth:`batch_weights`).

        Returns ``(base_w float32 [m], in_deg int64 [n])`` — the in-degree
        vector doubles as the degree state the streaming ticks continue
        from.
        """
        src = np.asarray(src, np.int64)
        dst = np.asarray(dst, np.int64)
        in_deg = np.zeros(n, np.int64)
        np.add.at(in_deg, dst, 1)
        raw64 = np.asarray(raw, np.float64)
        w = np.asarray(
            self.esusp(np, src, dst, raw64, in_deg[dst], aux), np.float64
        )
        w = np.broadcast_to(w, src.shape)
        # positive weights must stay positive through the snap
        w = np.maximum(quantize_susp_array(w), _QUANTUM)
        return w.astype(np.float32), in_deg

    def seed_vertices(
        self, n: int, in_deg: np.ndarray, aux: np.ndarray | None = None
    ) -> np.ndarray | None:
        """Vertex priors ``a_u`` for the base graph (snapped), or ``None``
        for the all-zero prior (lets services skip the buffer entirely)."""
        if self.vsusp is None:
            return None
        ids = np.arange(n, dtype=np.int64)
        a = np.asarray(self.vsusp(np, ids, np.asarray(in_deg, np.int64), aux),
                       np.float64)
        a = np.broadcast_to(a, (n,))
        if np.any(a < 0):
            raise ValueError(f"{self.name}: vsusp must be >= 0")
        return quantize_susp_array(a).astype(np.float32)

    # -- the streamed-tick rule (device side, jit-traceable) ----------------

    def batch_weights(self, in_deg, src, dst, raw, valid, aux=None):
        """Weight one streamed batch on device (jit-traceable).

        For ``uses_degree`` semantics each edge sees the destination degree
        *at its arrival* — stored degree plus earlier same-destination
        edges of the batch (exclusive running count), matching the host
        funnel's per-edge evaluation order — and the degree vector advances
        by the batch.  Weights are raw float32 (see module docstring for
        the quantization boundary); invalid lanes are zeroed.

        Returns ``(w float32 [B], new_in_deg)``.
        """
        import jax.numpy as jnp

        if self.uses_degree:
            ones = valid.astype(jnp.int32)
            same = (dst[:, None] == dst[None, :]) & valid[None, :] & valid[:, None]
            earlier = jnp.tril(same, k=-1).sum(axis=1)
            deg = in_deg[dst] + earlier
            new_deg = in_deg.at[dst].add(ones, mode="drop")
        else:
            deg = in_deg[dst]
            new_deg = in_deg
        w = self.esusp(jnp, src, dst, raw.astype(jnp.float32), deg, aux)
        w = jnp.where(valid, jnp.broadcast_to(w, src.shape).astype(jnp.float32),
                      0.0)
        return w, new_deg

    # -- host-plane adapter -------------------------------------------------

    def host_metric(self):
        """Compile this semantics into the host oracle's per-edge form
        (a :class:`~repro.core.metrics.DensityMetric`): scalar float64
        evaluation against the live :class:`AdjGraph`, snapped by the
        metric funnel.  The host plane has no aux channel — hooks receive
        ``aux = None``."""
        from .metrics import DensityMetric  # late: metrics imports this module

        sem = self

        def vsusp(u: int, g) -> float:
            if sem.vsusp is None:
                return 0.0
            deg = int(g.in_deg[u]) if u < g.n else 0
            out = sem.vsusp(np, np.asarray([u], np.int64),
                            np.asarray([deg], np.int64), None)
            return float(np.asarray(out, np.float64).reshape(-1)[0])

        def esusp(u: int, v: int, raw: float, g) -> float:
            deg = int(g.in_deg[v]) if v < g.n else 0
            out = sem.esusp(np, np.asarray([u], np.int64),
                            np.asarray([v], np.int64),
                            np.asarray([raw], np.float64),
                            np.asarray([deg], np.int64), None)
            return float(np.asarray(out, np.float64).reshape(-1)[0])

        return DensityMetric(name=sem.name, vsusp=vsusp, esusp=esusp)


# ---------------------------------------------------------------------------
# the registry: one table behind make_metric, the device seeding, and the
# service facade — registered names can never go stale in error messages
# ---------------------------------------------------------------------------

_REGISTRY: dict[str, SuspSemantics] = {}


def register(sem: SuspSemantics, overwrite: bool = False) -> SuspSemantics:
    """Register a semantics under its (case-insensitive) name; returns it,
    so it doubles as a definition-site decorator-ish helper."""
    key = sem.name.upper()
    if key in _REGISTRY and not overwrite and _REGISTRY[key] is not sem:
        raise ValueError(f"semantics {sem.name!r} already registered")
    _REGISTRY[key] = sem
    return sem


def available() -> tuple[str, ...]:
    """Registered semantics names (sorted)."""
    return tuple(sorted(_REGISTRY))


def resolve(semantics: SuspSemantics | str) -> SuspSemantics:
    """Look up a semantics by name, or pass an instance through."""
    if isinstance(semantics, SuspSemantics):
        return semantics
    try:
        return _REGISTRY[str(semantics).upper()]
    except KeyError:
        raise KeyError(
            f"unknown semantics {semantics!r}; choose from "
            f"{'/'.join(available())} or pass a SuspSemantics"
        ) from None


# ---------------------------------------------------------------------------
# paper instances (Appendix F), registered
# ---------------------------------------------------------------------------

DG = register(SuspSemantics(
    name="DG",
    # Charikar [6]: unweighted — every transaction counts 1
    esusp=lambda xp, src, dst, raw, deg, aux: xp.ones_like(raw),
))

DW = register(SuspSemantics(
    name="DW",
    # Gudapati et al. [18]: transaction amount (clamped positive)
    esusp=lambda xp, src, dst, raw, deg, aux: xp.maximum(raw, 1e-12),
))

_FD_C = 5.0

FD = register(SuspSemantics(
    name="FD",
    # Fraudar (Hooi [19]) column weighting: 1/log(x + C), x the destination
    # degree at arrival time
    esusp=lambda xp, src, dst, raw, deg, aux: 1.0 / xp.log(deg + _FD_C),
    uses_degree=True,
))
