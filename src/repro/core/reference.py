"""Exact host-plane reference implementation of Spade (paper-faithful oracle).

This module implements, with NumPy + ``heapq`` on the host CPU:

* **Algorithm 1** — the static peeling paradigm (Charikar-style greedy):
  iteratively remove the vertex with the smallest *peeling weight*
  ``w_u(S) = a_u + sum of incident edge suspiciousness within S`` and record
  the peeling sequence ``O`` and the peel-time weights ``Delta``.
* **Algorithm 2** — incremental peeling-sequence reordering in batch
  (the paper's core contribution): on edge insertions, only an *affected
  area* is re-examined using a pending priority queue ``T`` and black/gray/
  white coloring; the untouched prefix (Lemma 4.1) and the untouched tail
  are kept in place.

Every other implementation in this repo (the JAX exact peel, the bulk
parallel peel, the incremental suffix re-peel, and the Pallas kernels) is
validated against this module.

Determinism contract
--------------------
All vertex selections are ordered by the lexicographic key ``(weight, id)``
so that the incremental reorder provably reproduces the from-scratch
sequence even in the presence of ties.  Host arithmetic is float64; property
tests draw integer weights so cross-plane (float32 device) comparisons are
exact.

Density bookkeeping contract
----------------------------
``order``/``delta``/adjacency/``f0`` are *always exact* after each update
(this is what correctness proofs need).  Density sequences ``f(S_m)`` /
``g(S_m)`` are **derived on demand** in ``detect`` via one vectorized pass
(O(n) NumPy, milliseconds at millions of vertices), mirroring the paper's
C++ design which stores only ``_seq`` and ``_weight``.  The cached best
density used by the benign/urgent test is therefore conservative (never
stale-high in a way that hides fraud: a stale-low bound only makes *more*
edges urgent).
"""

from __future__ import annotations

import heapq
from dataclasses import dataclass, field
from typing import Callable, Iterable, Sequence

import numpy as np

__all__ = [
    "AdjGraph",
    "PeelState",
    "ReorderStats",
    "static_peel",
    "insert_edges",
    "delete_edge",
    "enumerate_communities",
    "detect",
    "peeling_weights_full",
]


# ---------------------------------------------------------------------------
# Graph
# ---------------------------------------------------------------------------


class AdjGraph:
    """Directed multigraph with per-vertex suspiciousness, stored undirected-
    combined for peeling (peeling weights are direction-agnostic, Eq. 2).

    ``adj[u][v]`` accumulates the total suspiciousness of all edges between
    ``u`` and ``v`` in either direction.  ``a[u]`` is the vertex
    suspiciousness. ``out_deg``/``in_deg`` track raw directed edge counts
    (used by e.g. Fraudar's column-weighting ``esusp``).
    """

    __slots__ = ("n", "adj", "a", "out_deg", "in_deg", "edge_weight_total", "m")

    def __init__(self, n: int = 0):
        self.n = int(n)
        self.adj: list[dict[int, float]] = [dict() for _ in range(self.n)]
        self.a = np.zeros(self.n, dtype=np.float64)
        self.out_deg = np.zeros(self.n, dtype=np.int64)
        self.in_deg = np.zeros(self.n, dtype=np.int64)
        self.edge_weight_total = 0.0
        self.m = 0  # directed edge count (multi-edges counted)

    # -- construction ------------------------------------------------------

    def add_vertex(self, a: float = 0.0) -> int:
        uid = self.n
        self.n += 1
        self.adj.append(dict())
        if self.a.shape[0] < self.n:
            grow = max(256, self.n)
            self.a = np.concatenate([self.a, np.zeros(grow)])
            self.out_deg = np.concatenate([self.out_deg, np.zeros(grow, np.int64)])
            self.in_deg = np.concatenate([self.in_deg, np.zeros(grow, np.int64)])
        self.a[uid] = float(a)
        return uid

    def add_edge(self, u: int, v: int, c: float) -> None:
        """Insert a directed edge with suspiciousness ``c > 0``."""
        if c <= 0:
            raise ValueError(f"edge suspiciousness must be > 0, got {c}")
        self.adj[u][v] = self.adj[u].get(v, 0.0) + c
        if u != v:
            self.adj[v][u] = self.adj[v].get(u, 0.0) + c
        self.out_deg[u] += 1
        self.in_deg[v] += 1
        self.edge_weight_total += c
        self.m += 1

    # -- queries -----------------------------------------------------------

    def f_total(self) -> float:
        """f(V): total suspiciousness of the whole graph (Eq. 1)."""
        return float(self.a[: self.n].sum()) + self.edge_weight_total

    def incident_weight(self, u: int) -> float:
        return sum(self.adj[u].values())

    def copy(self) -> "AdjGraph":
        g = AdjGraph(0)
        g.n = self.n
        g.adj = [dict(d) for d in self.adj]
        g.a = self.a.copy()
        g.out_deg = self.out_deg.copy()
        g.in_deg = self.in_deg.copy()
        g.edge_weight_total = self.edge_weight_total
        g.m = self.m
        return g

    @staticmethod
    def from_arrays(
        n: int,
        src: np.ndarray,
        dst: np.ndarray,
        c: np.ndarray,
        a: np.ndarray | None = None,
    ) -> "AdjGraph":
        g = AdjGraph(n)
        if a is not None:
            g.a[:n] = np.asarray(a, dtype=np.float64)
        for u, v, w in zip(
            np.asarray(src).tolist(), np.asarray(dst).tolist(), np.asarray(c).tolist()
        ):
            g.add_edge(int(u), int(v), float(w))
        return g


def peeling_weights_full(g: AdjGraph) -> np.ndarray:
    """w_u(S_0) = a_u + total incident suspiciousness, for every vertex."""
    w = g.a[: g.n].copy()
    for u in range(g.n):
        w[u] += sum(g.adj[u].values())
    return w


# ---------------------------------------------------------------------------
# Peel state
# ---------------------------------------------------------------------------

_HEADROOM = 1024  # buffer slots reserved in front for prepended new vertices


@dataclass
class PeelState:
    """Peeling sequence + peel-time weights over an :class:`AdjGraph`.

    Buffers are stored with a ``head`` offset so that vertex prepends (new
    vertices go to the head of the sequence, §4.1) are O(1).  ``pos_abs[u]``
    is the absolute buffer index of ``u``; its *rank* is
    ``pos_abs[u] - head``.
    """

    graph: AdjGraph
    order_buf: np.ndarray  # int64, vertex ids, valid in [head, head+n)
    delta_buf: np.ndarray  # float64, peel-time weights, aligned with order_buf
    pos_abs: np.ndarray  # int64, vertex id -> absolute buffer index
    head: int
    # conservative cache of the best community density (refreshed by detect())
    g_best_cache: float = 0.0

    @property
    def n(self) -> int:
        return self.graph.n

    def rank(self, u: int) -> int:
        return int(self.pos_abs[u]) - self.head

    def order(self) -> np.ndarray:
        """The peeling sequence O as a length-n array of vertex ids."""
        return self.order_buf[self.head : self.head + self.n]

    def delta(self) -> np.ndarray:
        """Peel-time weights Delta_i aligned with :meth:`order`."""
        return self.delta_buf[self.head : self.head + self.n]

    def _ensure_capacity(self, extra_head: int, extra_tail: int) -> None:
        need_head = extra_head - self.head
        cur_cap = self.order_buf.shape[0]
        need_tail = (self.head + self.n + extra_tail) - cur_cap
        if need_head <= 0 and need_tail <= 0:
            return
        grow_head = max(need_head, 0) + _HEADROOM
        grow_tail = max(need_tail, 0) + _HEADROOM
        new_cap = cur_cap + grow_head + grow_tail
        ob = np.empty(new_cap, dtype=np.int64)
        db = np.empty(new_cap, dtype=np.float64)
        ob[grow_head + self.head : grow_head + self.head + self.n] = self.order_buf[
            self.head : self.head + self.n
        ]
        db[grow_head + self.head : grow_head + self.head + self.n] = self.delta_buf[
            self.head : self.head + self.n
        ]
        self.order_buf, self.delta_buf = ob, db
        self.pos_abs = self.pos_abs + grow_head
        self.head += grow_head


@dataclass
class ReorderStats:
    """Affected-area instrumentation for one ``insert_edges`` call."""

    n_inserted_edges: int = 0
    n_new_vertices: int = 0
    n_pending: int = 0  # vertices that entered the pending queue T (|V_T|)
    n_edges_scanned: int = 0  # adjacency entries touched (|E_T|)
    n_appended_moved: int = 0  # vertices written back in processed windows
    n_windows: int = 0
    n_heap_ops: int = 0


# ---------------------------------------------------------------------------
# Algorithm 1: static peeling
# ---------------------------------------------------------------------------


def static_peel(g: AdjGraph) -> PeelState:
    """Run the peeling paradigm (Algorithm 1) from scratch.

    O(|E| log |V|) with a lazy-deletion binary heap.  Ties broken by vertex
    id (lexicographic ``(weight, id)`` key).
    """
    n = g.n
    w = peeling_weights_full(g)
    heap: list[tuple[float, int]] = [(w[u], u) for u in range(n)]
    heapq.heapify(heap)
    removed = np.zeros(n, dtype=bool)

    cap = n + 2 * _HEADROOM
    order_buf = np.empty(cap, dtype=np.int64)
    delta_buf = np.empty(cap, dtype=np.float64)
    pos_abs = np.empty(n, dtype=np.int64)
    head = _HEADROOM

    for step in range(n):
        while True:
            wu, u = heapq.heappop(heap)
            if not removed[u] and wu == w[u]:
                break
        removed[u] = True
        order_buf[head + step] = u
        delta_buf[head + step] = wu
        pos_abs[u] = head + step
        for v, c in g.adj[u].items():
            if not removed[v]:
                w[v] -= c
                heapq.heappush(heap, (w[v], v))

    state = PeelState(g, order_buf, delta_buf, pos_abs, head)
    # initialize the density cache exactly
    detect(state)
    return state


# ---------------------------------------------------------------------------
# Detection (argmax_g over the peel sequence) — vectorized, on demand
# ---------------------------------------------------------------------------


def detect(state: PeelState) -> tuple[np.ndarray, float]:
    """Return (community vertex ids S^P, g(S^P)).

    ``f(S_m) = sum_{j >= m} Delta_j`` (suffix sum of peel-time weights);
    ``g(S_m) = f(S_m) / (n - m)``; the best prefix set is returned.
    One vectorized O(n) pass; refreshes ``state.g_best_cache`` exactly.
    """
    n = state.n
    if n == 0:
        return np.empty(0, dtype=np.int64), 0.0
    delta = state.delta()
    f_suffix = np.cumsum(delta[::-1])[::-1]  # f_suffix[m] = f(S_m)
    sizes = n - np.arange(n)
    gseq = f_suffix / sizes
    best_m = int(np.argmax(gseq))
    g_best = float(gseq[best_m])
    state.g_best_cache = g_best
    return state.order()[best_m:].copy(), g_best


def density_sequence(state: PeelState) -> np.ndarray:
    """g(S_m) for m = 0..n-1 (diagnostics / tests)."""
    delta = state.delta()
    f_suffix = np.cumsum(delta[::-1])[::-1]
    return f_suffix / (state.n - np.arange(state.n))


# ---------------------------------------------------------------------------
# Algorithm 2: incremental peeling-sequence reordering in batch
# ---------------------------------------------------------------------------


def insert_edges(
    state: PeelState,
    edges: Sequence[tuple[int, int, float]],
    new_vertices: Sequence[tuple[int, float]] = (),
    stats: ReorderStats | None = None,
) -> ReorderStats:
    """Insert a batch of suspiciousness-weighted edges and reorder in place.

    Implements Algorithm 2 (batch reordering with black/gray/white coloring
    and peeling-weight recovery), generalized to also admit new vertices
    (prepended at the head of the sequence and treated as black so they sink
    to their correct position — this preserves *exact* equality with the
    from-scratch sequence, unlike a bare head insertion).

    Args:
      state: peel state; mutated in place (graph, order, delta, pos).
      edges: ``(u, v, c)`` directed edges with suspiciousness ``c > 0``;
        endpoints must already exist (use ``new_vertices`` first).
      new_vertices: ``(vertex_id, a)`` — ids must be exactly
        ``state.n, state.n+1, ...`` in order.
      stats: optional stats object to accumulate into.

    Returns the :class:`ReorderStats` for this call.
    """
    st = stats if stats is not None else ReorderStats()
    g = state.graph

    # ---- 0. apply new vertices (head prepend, colored black) -------------
    new_ids: list[int] = []
    for vid, a in new_vertices:
        got = g.add_vertex(a)
        if got != vid:
            raise ValueError(f"new vertex ids must be dense: expected {got}, got {vid}")
        new_ids.append(got)
    if new_ids:
        state._ensure_capacity(len(new_ids), 0)
        if state.pos_abs.shape[0] < g.n:
            grow = max(256, g.n - state.pos_abs.shape[0])
            state.pos_abs = np.concatenate(
                [state.pos_abs, np.zeros(grow, dtype=np.int64)]
            )
        # prepend in reverse so earlier ids sit earlier in the sequence
        for vid in reversed(new_ids):
            state.head -= 1
            state.order_buf[state.head] = vid
            # delta = a: recovery adds edge terms on top of the stored value.
            state.delta_buf[state.head] = g.a[vid]
            state.pos_abs[vid] = state.head
    st.n_new_vertices += len(new_ids)

    # ---- 1. apply edges to the graph --------------------------------------
    new_inc: dict[int, list[tuple[int, float]]] = {}
    for u, v, c in edges:
        g.add_edge(u, v, c)
        new_inc.setdefault(u, []).append((v, float(c)))
        if v != u:
            new_inc.setdefault(v, []).append((u, float(c)))
    st.n_inserted_edges += len(edges)

    dirty = set(new_inc.keys()) | set(new_ids)
    if not dirty:
        return st

    # ---- 2. reorder --------------------------------------------------------
    _reorder(state, dirty, new_inc, st)
    return st


def _reorder(
    state: PeelState,
    dirty: set[int],
    new_inc: dict[int, list[tuple[int, float]]],
    st: ReorderStats,
) -> None:
    g = state.graph
    n = state.n
    h = state.head
    order_buf = state.order_buf
    delta_buf = state.delta_buf
    pos_abs = state.pos_abs

    blacks = sorted(dirty, key=lambda u: pos_abs[u])
    black_ranks = [int(pos_abs[u]) - h for u in blacks]

    # pending queue T: lexicographic (weight, id); lazy deletion via wT
    T: list[tuple[float, int]] = []
    wT: dict[int, float] = {}
    in_T: set[int] = set()
    gray: set[int] = set()

    def recover_weight(u: int, k: int) -> float:
        """Current peeling weight of u w.r.t. remaining set T ∪ O[k:].

        = Delta_old(u) + old-edge weights to T members + new-edge weights to
        endpoints still remaining (rank > k, not in T).  ``adj`` already
        contains the new edges, so the T term uses the *updated* adjacency
        (covering new-edges-to-T exactly once) and the new-edge term is
        restricted to endpoints with rank > k outside T.
        """
        w = float(delta_buf[pos_abs[u]])
        au = g.adj[u]
        # old+new edges to pending vertices
        if len(in_T) < len(au):
            for v in in_T:
                c = au.get(v)
                if c is not None and v != u:
                    w += c
            st.n_edges_scanned += len(in_T)
        else:
            for v, c in au.items():
                if v in in_T:
                    w += c
            st.n_edges_scanned += len(au)
        # new edges to not-yet-scanned endpoints
        for v, c in new_inc.get(u, ()):
            if v not in in_T and (int(pos_abs[v]) - h) > k and v != u:
                w += c
        return w

    def push(u: int, w: float) -> None:
        wT[u] = w
        in_T.add(u)
        heapq.heappush(T, (w, u))
        st.n_heap_ops += 1
        st.n_pending += 1
        # color neighbors gray (affected-area frontier)
        gray.update(g.adj[u].keys())
        st.n_edges_scanned += len(g.adj[u])

    def pop_min() -> tuple[float, int]:
        while True:
            w, u = T[0]
            if u in in_T and wT[u] == w:
                heapq.heappop(T)
                st.n_heap_ops += 1
                in_T.discard(u)
                del wT[u]
                return w, u
            heapq.heappop(T)
            st.n_heap_ops += 1

    bi = 0  # index into blacks
    k = black_ranks[0] if black_ranks else n  # scan pointer (rank)
    newO: list[int] = []
    newD: list[float] = []
    w_start = k  # window start rank

    def flush(k_end: int) -> None:
        nonlocal newO, newD
        if not newO:
            return
        assert len(newO) == k_end - w_start, (len(newO), w_start, k_end)
        seg = np.asarray(newO, dtype=np.int64)
        order_buf[h + w_start : h + k_end] = seg
        delta_buf[h + w_start : h + k_end] = np.asarray(newD, dtype=np.float64)
        pos_abs[seg] = np.arange(h + w_start, h + k_end, dtype=np.int64)
        st.n_appended_moved += len(newO)
        st.n_windows += 1
        newO, newD = [], []

    while True:
        # activate any black vertex whose rank the scan pointer reached
        if bi < len(blacks) and k == black_ranks[bi]:
            u = blacks[bi]
            bi += 1
            push(u, recover_weight(u, k))
            k += 1
            continue

        if not in_T:
            # T drained: window closes here; jump to the next black vertex.
            flush(k)
            if bi >= len(blacks):
                break
            k = black_ranks[bi]
            w_start = k
            continue

        wmin, umin = T[0]
        while not (umin in in_T and wT[umin] == wmin):
            heapq.heappop(T)
            st.n_heap_ops += 1
            wmin, umin = T[0]

        if k >= n:
            # old sequence exhausted; drain T
            w, u = pop_min()
            newO.append(u)
            newD.append(w)
            for v, c in g.adj[u].items():
                if v in in_T:
                    wT[v] -= c
                    heapq.heappush(T, (wT[v], v))
                    st.n_heap_ops += 1
            st.n_edges_scanned += len(g.adj[u])
            continue

        uk = int(order_buf[h + k])
        dk = float(delta_buf[h + k])

        if (wmin, umin) < (dk, uk):
            # Case 1: pending head peels first
            w, u = pop_min()
            newO.append(u)
            newD.append(w)
            for v, c in g.adj[u].items():
                if v in in_T:
                    wT[v] -= c
                    heapq.heappush(T, (wT[v], v))
                    st.n_heap_ops += 1
            st.n_edges_scanned += len(g.adj[u])
        elif uk in gray:
            # Case 2(a): affected vertex — recover weight, move to T
            push(uk, recover_weight(uk, k))
            k += 1
        else:
            # Case 2(b): white vertex peels in place
            newO.append(uk)
            newD.append(dk)
            k += 1

    state.head = h  # unchanged (prepends already accounted)


# ---------------------------------------------------------------------------
# Convenience: full recompute for equivalence tests
# ---------------------------------------------------------------------------


def recompute(state: PeelState) -> PeelState:
    """From-scratch peel of the state's current graph (for tests)."""
    return static_peel(state.graph.copy())


# ---------------------------------------------------------------------------
# Appendix C.1: incremental edge deletion
# ---------------------------------------------------------------------------


def delete_edge(
    state: PeelState,
    u: int,
    v: int,
    c: float | None = None,
    stats: ReorderStats | None = None,
) -> ReorderStats:
    """Delete (all or ``c`` of) the edge weight between u and v and reorder
    incrementally (paper Appendix C.1).

    Deletion only *decreases* the endpoints' weights, so vertices may move
    EARLIER.  Phase 1 (downward scan): starting from the earlier endpoint's
    position, prefix vertices are pulled into the pending pool while their
    ``w(S_0)`` upper bound exceeds the pool's current exact minimum (the
    minimum is recomputed at each step — weights w.r.t. larger prefixes
    only grow, so the current value lower-bounds all earlier positions,
    making the stop test sound).  Phase 2: the forward merge of Algorithm 2
    with exact (direct-recompute) weight recovery.
    """
    st = stats if stats is not None else ReorderStats()
    g = state.graph
    if v not in g.adj[u]:
        raise KeyError(f"no edge between {u} and {v}")
    w_edge = g.adj[u][v] if c is None else float(c)
    if w_edge > g.adj[u][v] + 1e-12:
        raise ValueError("cannot delete more weight than present")
    if abs(g.adj[u][v] - w_edge) < 1e-15:
        del g.adj[u][v]
        if u != v:
            del g.adj[v][u]
    else:
        g.adj[u][v] -= w_edge
        if u != v:
            g.adj[v][u] -= w_edge
    g.edge_weight_total -= w_edge
    st.n_inserted_edges += 1  # counted as one update

    h = state.head
    order_buf, delta_buf, pos_abs = state.order_buf, state.delta_buf, state.pos_abs
    n = state.n
    i_hi = min(state.rank(u), state.rank(v))

    members: set[int] = {u, v}

    def direct_weight(x: int, k: int) -> float:
        """Exact current weight of x w.r.t. members ∪ O[k:] (minus peeled)."""
        w = float(g.a[x])
        for y, cw in g.adj[x].items():
            if y == x:
                continue
            if y in members or (int(pos_abs[y]) - h) >= k:
                w += cw
        st.n_edges_scanned += len(g.adj[x])
        return w

    # --- phase 1: downward scan -------------------------------------------
    # Stop at k0 only when EVERY remaining prefix position certifiably peels
    # before every pool member: lexicographic (Δ_j, id_j) < pool minimum
    # (prefix deltas are unchanged by the deletion — the endpoints sit at
    # ranks >= i_hi).  Violating positions (and everything after them) are
    # pulled into the pool and re-merged in phase 2.
    k0 = i_hi
    while k0 > 0:
        pool_w, pool_id = min((direct_weight(t, k0), t) for t in members)
        dd = delta_buf[h : h + k0]
        oo = order_buf[h : h + k0]
        viol = (dd > pool_w) | ((dd == pool_w) & (oo > pool_id))
        idx = np.nonzero(viol)[0]
        if idx.size == 0:
            break
        j = int(idx.max())
        for kk in range(j, k0):
            members.add(int(order_buf[h + kk]))
        k0 = j

    # --- phase 2: forward merge (Algorithm 2 with exact recovery) ----------
    T: list[tuple[float, int]] = []
    wT: dict[int, float] = {}
    gray: set[int] = set()
    for x in members:
        w = direct_weight(x, k0)
        wT[x] = w
        heapq.heappush(T, (w, x))
        gray.update(g.adj[x].keys())
        st.n_pending += 1
        st.n_heap_ops += 1
    consumed = set(members)

    newO: list[int] = []
    newD: list[float] = []
    k = k0

    def pop_min():
        while True:
            w, x = heapq.heappop(T)
            st.n_heap_ops += 1
            if x in wT and wT[x] == w:
                del wT[x]
                members.discard(x)  # peeled: no longer counts in recovery
                return w, x

    def pop_and_append():
        w, x = pop_min()
        newO.append(x)
        newD.append(w)
        for y, cw in g.adj[x].items():
            if y in wT:
                wT[y] -= cw
                heapq.heappush(T, (wT[y], y))
                st.n_heap_ops += 1
        st.n_edges_scanned += len(g.adj[x])

    while True:
        while k < n and int(order_buf[h + k]) in consumed:
            k += 1
        if not wT:
            break
        if k >= n:
            pop_and_append()
            continue
        uk = int(order_buf[h + k])
        dk = float(delta_buf[h + k])
        wmin, umin = T[0]
        while not (umin in wT and wT[umin] == wmin):
            heapq.heappop(T)
            st.n_heap_ops += 1
            wmin, umin = T[0]
        if (wmin, umin) < (dk, uk):
            pop_and_append()
        elif uk in gray:
            members.add(uk)  # direct_weight counts it as pending
            wT[uk] = direct_weight(uk, k + 1)
            heapq.heappush(T, (wT[uk], uk))
            st.n_heap_ops += 1
            st.n_pending += 1
            gray.update(g.adj[uk].keys())
            consumed.add(uk)
            k += 1
        else:
            newO.append(uk)
            newD.append(dk)
            consumed.add(uk)
            k += 1

    # splice: [k0, k0+len(newO)) := newO, untouched tail (old ranks >= k) follows
    tail_o = order_buf[h + k : h + n].copy()
    tail_d = delta_buf[h + k : h + n].copy()
    seg = np.asarray(newO, dtype=np.int64)
    order_buf[h + k0 : h + k0 + seg.shape[0]] = seg
    delta_buf[h + k0 : h + k0 + seg.shape[0]] = np.asarray(newD)
    order_buf[h + k0 + seg.shape[0] : h + n] = tail_o
    delta_buf[h + k0 + seg.shape[0] : h + n] = tail_d
    pos_abs[order_buf[h + k0 : h + n]] = np.arange(h + k0, h + n)
    st.n_appended_moved += len(newO)
    st.n_windows += 1
    return st


# ---------------------------------------------------------------------------
# Appendix C.2: dense-subgraph enumeration
# ---------------------------------------------------------------------------


def enumerate_communities(g: AdjGraph, max_k: int = 5, min_density: float = 0.0):
    """Recursively peel, report, remove (paper C.2, static form).

    Returns a list of (vertex ids in ORIGINAL numbering, density), in
    discovery (decreasing-density) order.
    """
    work = g.copy()
    ids = np.arange(g.n)  # work-index -> original id
    out = []
    for _ in range(max_k):
        if work.n == 0 or work.f_total() <= 0:
            break
        st = static_peel(work.copy())
        comm, dens = detect(st)
        if dens <= min_density or comm.shape[0] == 0:
            break
        out.append((ids[comm], dens))
        comm_set = set(comm.tolist())
        keep = [x for x in range(work.n) if x not in comm_set]
        if not keep:
            break
        remap = {x: i for i, x in enumerate(keep)}
        g2 = AdjGraph(len(keep))
        g2.a[: len(keep)] = work.a[keep]
        for x in keep:
            for y, cw in work.adj[x].items():
                if y in remap and x < y:
                    g2.add_edge(remap[x], remap[y], cw)
                elif y == x:
                    g2.add_edge(remap[x], remap[x], cw)
        ids = ids[keep]
        work = g2
    return out
