"""Density metrics and the VSusp/ESusp programmability API (paper §3).

A *density metric* is ``g(S) = f(S)/|S|`` with
``f(S) = Σ a_i + Σ c_ij`` (Eq. 1).  Spade supports any metric expressible
through two user hooks (Property 3.1: arithmetic density, ``a_i ≥ 0``,
``c_ij > 0``):

* ``vsusp(u, graph) -> a_u``   — vertex suspiciousness (prior/side info)
* ``esusp(u, v, graph) -> c``  — edge suspiciousness, evaluated at edge
  arrival time (the paper's C++ snippet reads the live degree, so e.g.
  Fraudar's column weighting uses the destination degree *at insertion*).

Instances (paper Appendix F):

* **DG**  (Charikar [6])        — ``esusp = 1``,   ``vsusp = 0``
* **DW**  (Gudapati et al. [18])— ``esusp = c_ij`` (transaction amount)
* **FD**  (Fraudar, Hooi [19])  — ``vsusp = a_u`` side info,
  ``esusp = 1/log(deg(dst) + C)`` with ``C = 5``
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Callable

from .reference import AdjGraph

__all__ = ["DensityMetric", "DG", "DW", "FD", "make_metric", "quantize_susp",
           "quantize_susp_array"]

VSuspFn = Callable[[int, AdjGraph], float]
ESuspFn = Callable[[int, int, float, AdjGraph], float]

# Suspiciousness values are snapped to a dyadic grid (multiples of 2^-30)
# at the metric funnel.  Rationale (determinism contract, reference.py):
# the incremental reorder recovers peeling weights as Delta_old + edge
# terms while the from-scratch peel runs a running subtraction — different
# float64 summation orders.  Irrational metric values (FD's 1/log) then
# drift by an ulp between the two runs and the (weight, id) tie-break
# resolves "equal" weights differently.  Grid values with magnitude below
# 2^23 sum *exactly* in float64 in any order, so ties are exact ties and
# the vertex-id tie-break is stable across incremental and scratch runs.
# The 2^-30 (~1e-9 relative) snap is far below any fraud-semantics signal.
_QUANT_BITS = 30
_QUANTUM = math.ldexp(1.0, -_QUANT_BITS)


def quantize_susp(x: float) -> float:
    """Round a suspiciousness value to the shared dyadic grid."""
    return math.ldexp(round(math.ldexp(x, _QUANT_BITS)), -_QUANT_BITS)


def quantize_susp_array(x):
    """Vectorized :func:`quantize_susp` (numpy, float64 intermediate).

    ``np.rint`` rounds half-to-even exactly like the scalar ``round``, so
    host-plane per-edge quantization and device-plane batch seeding land
    on identical grid points — the single definition both planes share.
    """
    import numpy as np

    return np.ldexp(
        np.rint(np.ldexp(np.asarray(x, np.float64), _QUANT_BITS)), -_QUANT_BITS
    )


@dataclass(frozen=True)
class DensityMetric:
    """A pluggable fraud-semantics definition (the paper's VSusp/ESusp pair).

    ``esusp`` receives ``(src, dst, raw_weight, graph)`` where ``raw_weight``
    is the application payload on the transaction (e.g. amount); it must
    return a strictly positive suspiciousness.  ``vsusp`` receives
    ``(vertex, graph)`` and must return a nonnegative prior.
    """

    name: str
    vsusp: VSuspFn
    esusp: ESuspFn

    def vertex_susp(self, u: int, g: AdjGraph) -> float:
        a = float(self.vsusp(u, g))
        if a < 0:
            raise ValueError(f"{self.name}: vsusp must be >= 0, got {a}")
        return quantize_susp(a)

    def edge_susp(self, u: int, v: int, raw: float, g: AdjGraph) -> float:
        c = float(self.esusp(u, v, raw, g))
        if c <= 0:
            raise ValueError(f"{self.name}: esusp must be > 0, got {c}")
        # positive weights must stay positive through the snap
        return max(quantize_susp(c), _QUANTUM)


# ---------------------------------------------------------------------------
# Paper instances
# ---------------------------------------------------------------------------

DG = DensityMetric(
    name="DG",
    vsusp=lambda u, g: 0.0,
    esusp=lambda u, v, raw, g: 1.0,
)

DW = DensityMetric(
    name="DW",
    vsusp=lambda u, g: 0.0,
    esusp=lambda u, v, raw, g: max(float(raw), 1e-12),
)


def _fd_esusp(u: int, v: int, raw: float, g: AdjGraph, C: float = 5.0) -> float:
    # Fraudar column weighting: 1/log(x + C) with x the degree of the object
    # (destination) vertex at arrival time.
    x = float(g.in_deg[v]) if v < g.n else 0.0
    return 1.0 / math.log(x + C)


def make_fd(vertex_prior: Callable[[int], float] | None = None) -> DensityMetric:
    """Fraudar with an optional per-vertex side-information prior."""
    prior = vertex_prior or (lambda u: 0.0)
    return DensityMetric(
        name="FD",
        vsusp=lambda u, g: float(prior(u)),
        esusp=_fd_esusp,
    )


FD = make_fd()

_REGISTRY = {"DG": DG, "DW": DW, "FD": FD, "dg": DG, "dw": DW, "fd": FD}


def make_metric(name: str) -> DensityMetric:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown metric {name!r}; choose from DG/DW/FD") from None
