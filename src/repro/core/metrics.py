"""Host-plane density metrics: the per-edge compiled form of a semantics.

A *density metric* is ``g(S) = f(S)/|S|`` with
``f(S) = Σ a_i + Σ c_ij`` (Eq. 1).  Spade supports any metric expressible
through two user hooks (Property 3.1: arithmetic density, ``a_i ≥ 0``,
``c_ij > 0``):

* ``vsusp(u, graph) -> a_u``   — vertex suspiciousness (prior/side info)
* ``esusp(u, v, graph) -> c``  — edge suspiciousness, evaluated at edge
  arrival time (the paper's C++ snippet reads the live degree, so e.g.
  Fraudar's column weighting uses the destination degree *at insertion*).

This module is now a **thin adapter** over the pluggable semantics plane
(:mod:`repro.core.semantics`): the canonical DG/DW/FD definitions live
there as :class:`~repro.core.semantics.SuspSemantics` instances (one
definition compiled into every engine), and the host-plane objects below
are their :meth:`~repro.core.semantics.SuspSemantics.host_metric`
projections.  ``DensityMetric`` remains the host oracle's per-edge funnel:
scalar evaluation plus the dyadic-grid snap (the quantization boundary —
see semantics.py for the determinism rationale).

One registry backs everything: :func:`make_metric` resolves through
``semantics.resolve``, so its error message can never drift from the set
of semantics the device planes accept.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

from .reference import AdjGraph
from .semantics import (
    _QUANTUM,
    SuspSemantics,
    quantize_susp,
    quantize_susp_array,
    resolve,
)
from .semantics import DG as DG_SEMANTICS
from .semantics import DW as DW_SEMANTICS
from .semantics import FD as FD_SEMANTICS

__all__ = ["DensityMetric", "DG", "DW", "FD", "make_fd", "make_metric",
           "quantize_susp", "quantize_susp_array"]

VSuspFn = Callable[[int, AdjGraph], float]
ESuspFn = Callable[[int, int, float, AdjGraph], float]


@dataclass(frozen=True)
class DensityMetric:
    """A host-plane fraud-semantics definition (the paper's VSusp/ESusp pair).

    ``esusp`` receives ``(src, dst, raw_weight, graph)`` where ``raw_weight``
    is the application payload on the transaction (e.g. amount); it must
    return a strictly positive suspiciousness.  ``vsusp`` receives
    ``(vertex, graph)`` and must return a nonnegative prior.
    """

    name: str
    vsusp: VSuspFn
    esusp: ESuspFn

    def vertex_susp(self, u: int, g: AdjGraph) -> float:
        a = float(self.vsusp(u, g))
        if a < 0:
            raise ValueError(f"{self.name}: vsusp must be >= 0, got {a}")
        return quantize_susp(a)

    def edge_susp(self, u: int, v: int, raw: float, g: AdjGraph) -> float:
        c = float(self.esusp(u, v, raw, g))
        if c <= 0:
            raise ValueError(f"{self.name}: esusp must be > 0, got {c}")
        # positive weights must stay positive through the snap
        return max(quantize_susp(c), _QUANTUM)


# ---------------------------------------------------------------------------
# Paper instances (host projections of the registered semantics)
# ---------------------------------------------------------------------------

DG = DG_SEMANTICS.host_metric()
DW = DW_SEMANTICS.host_metric()


def make_fd(vertex_prior: Callable[[int], float] | None = None) -> DensityMetric:
    """Fraudar with an optional per-vertex side-information prior."""
    base = FD_SEMANTICS.host_metric()
    if vertex_prior is None:
        return base
    return DensityMetric(
        name="FD",
        vsusp=lambda u, g: float(vertex_prior(u)),
        esusp=base.esusp,
    )


FD = make_fd()


def make_metric(
    metric: DensityMetric | SuspSemantics | str,
) -> DensityMetric:
    """Resolve a metric/semantics spec to the host-plane compiled form.

    Accepts a registered semantics name (``"DG"``/``"DW"``/``"FD"``/any
    user-registered name, case-insensitive), a :class:`SuspSemantics`
    (compiled via its host adapter), or a ready ``DensityMetric`` (passed
    through).  The name lookup and the error message both come from the
    single semantics registry, shared with the device-plane seeding.
    """
    if isinstance(metric, DensityMetric):
        return metric
    return resolve(metric).host_metric()
