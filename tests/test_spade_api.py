"""Behavioural tests for the Spade public API (Listing 1) + edge grouping."""

from __future__ import annotations

import numpy as np
import pytest

from repro.core import DG, DW, Spade, make_fd, static_peel
from repro.core.reference import AdjGraph, detect


def build_background(rng, n=40, m=100):
    src = rng.integers(0, n, m)
    dst = rng.integers(0, n, m)
    keep = src != dst
    return n, src[keep], dst[keep], np.ones(keep.sum())


def test_load_detect_dg():
    rng = np.random.default_rng(0)
    n, src, dst, w = build_background(rng)
    sp = Spade(metric="DG")
    sp.LoadGraph(src, dst, w, n_vertices=n)
    comm, gb = sp.Detect()
    assert gb > 0 and len(comm) > 0


@pytest.mark.parametrize("metric", ["DG", "DW", "FD"])
def test_insert_edge_matches_scratch(metric):
    """FD included: suspiciousness values are snapped to a dyadic grid at
    the metric funnel (metrics.quantize_susp), so the incremental reorder's
    recovered weights and the scratch peel's running subtraction sum
    *exactly* and the (weight, id) tie-break is id-stable in both runs."""
    rng = np.random.default_rng(1)
    n, src, dst, w = build_background(rng)
    sp = Spade(metric=metric)
    sp.LoadGraph(src, dst, w, n_vertices=n)
    for _ in range(25):
        u, v = rng.integers(0, n, 2)
        if u == v:
            continue
        sp.InsertEdge(int(u), int(v), float(rng.integers(1, 5)))
    # incremental state == from-scratch peel of the maintained graph
    expect = static_peel(sp.graph.copy())
    np.testing.assert_array_equal(sp.state.order(), expect.order())
    np.testing.assert_allclose(sp.state.delta(), expect.delta())


def test_fd_tie_break_regression_seed1():
    """Regression for the formerly-xfailed divergence: seed 1 at insert #13
    used to produce two vertices whose exact-arithmetic-equal FD weights
    differed by one ulp between the recovered and scratch computations
    (2.1742422504435974 vs ...97), reversing their tie order.  With grid-
    snapped weights the sums are exact and the order must stay identical
    after every single insert."""
    rng = np.random.default_rng(1)
    n, src, dst, w = build_background(rng)
    sp = Spade(metric="FD")
    sp.LoadGraph(src, dst, w, n_vertices=n)
    for _ in range(16):  # covers the historically divergent insert #13
        u, v = rng.integers(0, n, 2)
        if u == v:
            continue
        sp.InsertEdge(int(u), int(v), float(rng.integers(1, 5)))
        expect = static_peel(sp.graph.copy())
        np.testing.assert_array_equal(sp.state.order(), expect.order())
        np.testing.assert_allclose(sp.state.delta(), expect.delta())


def test_delete_edge_explicit_amount_is_grid_snapped():
    """Regression: DeleteEdge(c=raw_amount) must snap c through the same
    dyadic grid the stored weights went through — otherwise 0.1 raises
    'cannot delete more weight than present' (stored quantize(0.1) is a
    hair below 0.1) and 0.7 leaves a ~2e-10 residual live edge."""
    sp = Spade(metric="DW")
    sp.LoadGraph([0, 1, 2], [1, 2, 0], [0.1, 0.7, 1.0], n_vertices=3)
    sp.DeleteEdge(0, 1, 0.1)  # raw amount quantized down at insert
    assert 1 not in sp.graph.adj[0]
    sp.DeleteEdge(1, 2, 0.7)  # raw amount quantized up at insert
    assert 2 not in sp.graph.adj[1]
    expect = static_peel(sp.graph.copy())
    np.testing.assert_array_equal(sp.state.order(), expect.order())
    np.testing.assert_allclose(sp._w0[:3], [1.0, 0.0, 1.0], atol=1e-9)


def test_quantize_susp_grid_is_exact():
    """Grid values sum exactly in float64 in any order (the property the
    determinism contract rests on)."""
    import math

    from repro.core.metrics import quantize_susp

    vals = [quantize_susp(1.0 / math.log(x + 5.0)) for x in range(200)]
    fwd = 0.0
    for v in vals:
        fwd += v
    rev = 0.0
    for v in reversed(vals):
        rev += v
    assert fwd == rev  # bit-identical, not just close
    assert all(quantize_susp(v) == v for v in vals)  # idempotent


def test_fraud_block_detected_and_reported():
    rng = np.random.default_rng(2)
    n, src, dst, w = build_background(rng, n=60, m=80)
    sp = Spade(metric="DW")
    sp.LoadGraph(src, dst, w, n_vertices=n)
    block = list(range(8))
    seen_new = set()
    for u in block:
        for v in block:
            if u < v:
                res = sp.InsertEdge(u, v, 20.0)
                seen_new.update(res.new_fraudsters.tolist())
    comm, _ = sp.Detect()
    assert set(block).issubset(set(comm.tolist()))
    assert set(block).issubset(seen_new | set(block) & set(comm.tolist()))


def test_edge_grouping_buffers_benign_and_flushes():
    rng = np.random.default_rng(3)
    n, src, dst, w = build_background(rng, n=50, m=150)
    sp = Spade(metric="DG", edge_grouping=True)
    sp.LoadGraph(src, dst, w, n_vertices=n)
    g0 = sp.Detect()[1]
    # find a benign edge: low-degree endpoints, tiny weight
    res = None
    for _ in range(100):
        u, v = int(rng.integers(0, n)), int(rng.integers(0, n))
        if u == v:
            continue
        if sp._w0[u] + 1.0 < g0 and sp._w0[v] + 1.0 < g0:
            res = sp.InsertEdge(u, v, 1.0)
            break
    if res is not None:
        assert not res.triggered and res.buffered >= 1
    # urgent edge: attach heavy weight to the current community
    comm, _ = sp.Detect()
    res2 = sp.InsertEdge(int(comm[0]), int(comm[-1]), 100.0)
    assert res2.triggered and sp.buffered_edges == 0
    # after flush everything must equal from-scratch
    sp.FlushBuffer()
    expect = static_peel(sp.graph.copy())
    np.testing.assert_array_equal(sp.state.order(), expect.order())


def test_edge_grouping_deferral_is_safe():
    """Lemma 4.3/4.4: benign edges cannot create a denser community, so the
    buffered state's community density matches scratch on flush."""
    rng = np.random.default_rng(4)
    n, src, dst, w = build_background(rng, n=40, m=120)
    sp = Spade(metric="DG", edge_grouping=True)
    sp.LoadGraph(src, dst, w, n_vertices=n)
    for _ in range(30):
        u, v = int(rng.integers(0, n)), int(rng.integers(0, n))
        if u != v:
            sp.InsertEdge(u, v, 1.0)
    sp.FlushBuffer()
    expect = static_peel(sp.graph.copy())
    _, g_expect = detect(expect)
    _, g_got = sp.Detect()
    assert np.isclose(g_got, g_expect)


def test_new_vertices_via_api():
    sp = Spade(metric="DW")
    sp.LoadGraph([0, 1], [1, 2], [1.0, 1.0], n_vertices=3)
    sp.InsertEdge(3, 0, 5.0)  # vertex 3 is new
    sp.InsertEdge(4, 3, 2.0)  # vertex 4 is new
    assert sp.graph.n == 5
    expect = static_peel(sp.graph.copy())
    np.testing.assert_array_equal(sp.state.order(), expect.order())


def test_custom_vsusp_esusp_hooks():
    sp = Spade(metric="DG")
    sp.VSusp(lambda u, g: 2.0)
    sp.ESusp(lambda u, v, raw, g: raw * 3.0)
    sp.LoadGraph([0, 1], [1, 2], [1.0, 2.0], n_vertices=3)
    assert sp.graph.a[0] == 2.0
    assert sp.graph.adj[0][1] == 3.0
    assert sp.graph.adj[1][2] == 6.0


def test_fd_metric_degree_weighting():
    fd = make_fd()
    g = AdjGraph(3)
    g.add_edge(0, 2, 1.0)
    c1 = fd.edge_susp(0, 2, 1.0, g)
    g.add_edge(1, 2, 1.0)
    c2 = fd.edge_susp(1, 2, 1.0, g)
    assert c2 < c1  # busier object vertex => less suspicious per edge


def test_batch_admits_new_vertices_via_separate_edges():
    """Regression: within one InsertBatchEdges call, vertices admitted by
    earlier edges of the same batch live in the pending list, so a batch
    introducing two new vertices via separate edges must not trip the
    dense-id check."""
    sp = Spade(metric="DW")
    sp.LoadGraph([0, 1], [1, 2], [1.0, 1.0], n_vertices=3)
    res = sp.InsertBatchEdges([(0, 3, 2.0), (1, 4, 2.0)])  # 3 and 4 are new
    assert sp.graph.n == 5
    assert res.triggered
    expect = static_peel(sp.graph.copy())
    np.testing.assert_array_equal(sp.state.order(), expect.order())
    # same shape of batch, but buffered through edge grouping: pending new
    # vertices interleave with the benign buffer's new-vertex list
    sp2 = Spade(metric="DW", edge_grouping=True)
    # heavy triangle 0-1-2 (g(S^P) high) + light vertex 3
    sp2.LoadGraph([0, 1, 2, 0], [1, 2, 0, 3], [100.0, 100.0, 100.0, 1.0],
                  n_vertices=4)
    r1 = sp2.InsertBatchEdges([(3, 4, 0.1), (3, 5, 0.1)])  # 4, 5 new, benign
    assert not r1.triggered and r1.buffered == 2
    r2 = sp2.InsertBatchEdges([(4, 6, 0.1), (5, 7, 0.1)])  # 6, 7 new, benign
    assert not r2.triggered
    out = sp2.FlushBuffer()
    assert sp2.graph.n == 8
    assert out.triggered
    expect2 = static_peel(sp2.graph.copy())
    np.testing.assert_array_equal(sp2.state.order(), expect2.order())
