"""repro.dist: logical-axis sharding, EF compression plumbing, and
mesh-sharded peeling parity against the single-device engine.

The sharded tests need >= 2 XLA host devices; conftest.py forces 8 via
``--xla_force_host_platform_device_count`` before jax initializes."""

from __future__ import annotations

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.incremental import (
    delete_and_maintain,
    full_refresh,
    init_state,
    insert_and_maintain,
    insert_and_maintain_auto,
    slide_and_maintain_auto,
)
from repro.core.peel import bulk_peel
from repro.dist.compression import ef_compress_tree
from repro.dist.graph import (
    init_sharded_state,
    shard_graph,
    sharded_bulk_peel,
    sharded_delete_and_maintain,
    sharded_full_refresh,
    sharded_insert_and_maintain,
    sharded_insert_and_maintain_auto,
    sharded_peel_weights,
    sharded_slide_and_maintain_auto,
)
from repro.dist.sharding import (
    AxisEnv,
    axis_env,
    constrain,
    tree_shardings,
    use_axis_env,
)
from repro.graphstore.structs import device_graph_from_coo

multi_device = pytest.mark.skipif(
    len(jax.devices()) < 2, reason="needs >=2 XLA host devices"
)


def data_mesh(n: int):
    return jax.make_mesh((n,), ("data",))


def random_graph(seed: int, n: int = 200, m: int = 900, e_slack: int = 512):
    """Integer weights -> order-independent f32 sums -> exact parity."""
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n, m)
    dst = rng.integers(0, n, m)
    keep = src != dst
    src, dst = src[keep], dst[keep]
    c = rng.integers(1, 6, src.shape[0]).astype(np.float32)
    a = rng.integers(0, 3, n).astype(np.float32)
    return device_graph_from_coo(n, src, dst, c, a, e_capacity=src.shape[0] + e_slack)


# ---------------------------------------------------------------------------
# sharding: the logical-axis layer
# ---------------------------------------------------------------------------


def test_constrain_is_noop_without_env():
    x = jnp.ones((8, 4))
    assert constrain(x, "batch", None) is x
    assert axis_env() is None


@multi_device
def test_axis_env_resolution_and_constrain():
    mesh = jax.make_mesh((2, len(jax.devices()) // 2), ("data", "model"))
    env = AxisEnv(mesh=mesh)
    # 'pod' absent -> batch lands on data alone; expert rides model
    assert env.resolve("batch") == "data"
    assert env.resolve("expert") == "model"
    assert env.resolve("edges") == "data"
    assert env.axis_size("batch") == 2
    with use_axis_env(env):
        assert axis_env() is env

        @jax.jit
        def f(x):
            return constrain(x, "batch", "model") * 2.0

        x = jnp.ones((8, mesh.shape["model"] * 2))
        np.testing.assert_array_equal(np.asarray(f(x)), 2.0 * np.ones(x.shape))
        # non-divisible dim: constraint dropped, still works
        y = jnp.ones((3, 5))
        np.testing.assert_array_equal(
            np.asarray(jax.jit(lambda v: constrain(v, "batch", "model"))(y)),
            np.ones((3, 5)),
        )
    assert axis_env() is None


@multi_device
def test_tree_shardings_maps_logical_tuples():
    mesh = data_mesh(len(jax.devices()))
    env = AxisEnv(mesh=mesh)
    logical = {"w": ("batch", None), "scalar": (), "nested": {"e": ("edges",)}}
    with use_axis_env(env):
        sh = tree_shardings(logical)
    assert sh["w"] == NamedSharding(mesh, P("data", None))
    assert sh["scalar"] == NamedSharding(mesh, P())
    assert sh["nested"]["e"] == NamedSharding(mesh, P("data"))


def test_tree_shardings_requires_mesh():
    with pytest.raises(ValueError):
        tree_shardings({"w": ("batch",)})


def test_axis_env_rule_override_and_unknown():
    env = AxisEnv(mesh=None, rules={"batch": ()})
    assert env.resolve("batch") is None
    with pytest.raises(KeyError):
        AxisEnv().rule("no_such_axis")


def test_ef_compress_tree_initializes_err():
    g = {"w": jnp.asarray([0.1, -0.2, 0.3]), "b": jnp.asarray([1.0])}
    deq, err = ef_compress_tree(g)
    assert jax.tree.structure(deq) == jax.tree.structure(g)
    # accumulated signal tracks: g == deq + err per leaf
    for k in g:
        np.testing.assert_allclose(
            np.asarray(deq[k]) + np.asarray(err[k]), np.asarray(g[k]), atol=1e-6
        )


# ---------------------------------------------------------------------------
# graph: mesh-sharded peeling == single-device engine
# ---------------------------------------------------------------------------


@multi_device
def test_shard_graph_pads_and_places():
    g = random_graph(0, e_slack=3)  # e_capacity not divisible by 8
    mesh = data_mesh(len(jax.devices()))
    gs = shard_graph(g, mesh)
    assert gs.e_capacity % len(jax.devices()) == 0
    assert gs.n_capacity == g.n_capacity
    assert int(gs.n_edges) == int(g.n_edges)
    np.testing.assert_allclose(np.asarray(g.peel_weights()),
                               np.asarray(sharded_peel_weights(gs, mesh)))


@multi_device
@pytest.mark.parametrize("seed", range(3))
def test_sharded_bulk_peel_matches_single_device(seed):
    g = random_graph(seed)
    mesh = data_mesh(len(jax.devices()))
    ref = bulk_peel(g, eps=0.1)
    res = sharded_bulk_peel(shard_graph(g, mesh), mesh, eps=0.1)
    assert float(res.best_g) == float(ref.best_g)
    assert int(res.n_rounds) == int(ref.n_rounds)
    np.testing.assert_array_equal(np.asarray(res.level), np.asarray(ref.level))
    np.testing.assert_array_equal(
        np.asarray(res.community_mask()), np.asarray(ref.community_mask())
    )


@multi_device
def test_sharded_bulk_peel_two_way_mesh():
    g = random_graph(7)
    mesh = data_mesh(2)
    res = sharded_bulk_peel(shard_graph(g, mesh), mesh, eps=0.1)
    ref = bulk_peel(g, eps=0.1)
    assert float(res.best_g) == float(ref.best_g)
    np.testing.assert_array_equal(np.asarray(res.level), np.asarray(ref.level))


@multi_device
def test_sharded_incremental_matches_single_device():
    """Streamed batches: append, warm re-peel, w0 and community all track
    the single-device engine bit-for-bit (integer weights)."""
    n = 200
    g = random_graph(1, n=n)
    mesh = data_mesh(len(jax.devices()))
    rng = np.random.default_rng(2)
    st_ref = init_state(g, eps=0.1)
    st_sh = init_sharded_state(shard_graph(g, mesh), mesh, eps=0.1)
    for step in range(4):
        B = 64
        bs = jnp.asarray(rng.integers(0, n, B), jnp.int32)
        bd = jnp.asarray(rng.integers(0, n, B), jnp.int32)
        bc = jnp.asarray(rng.integers(1, 4, B), jnp.float32)
        valid = bs != bd
        st_ref = insert_and_maintain(st_ref, bs, bd, bc, valid, eps=0.1)
        st_sh = sharded_insert_and_maintain(
            st_sh, bs, bd, bc, valid, mesh=mesh, eps=0.1
        )
        assert float(st_sh.best_g) == float(st_ref.best_g), step
        assert int(st_sh.edge_count) == int(st_ref.edge_count)
        np.testing.assert_array_equal(
            np.asarray(st_sh.level), np.asarray(st_ref.level)
        )
        np.testing.assert_array_equal(
            np.asarray(st_sh.community), np.asarray(st_ref.community)
        )
        np.testing.assert_allclose(np.asarray(st_sh.w0), np.asarray(st_ref.w0))
        E = st_ref.graph.e_capacity  # sharded graph may be tail-padded
        np.testing.assert_array_equal(
            np.asarray(st_sh.graph.src)[:E], np.asarray(st_ref.graph.src)
        )
        np.testing.assert_array_equal(
            np.asarray(st_sh.graph.edge_mask)[:E],
            np.asarray(st_ref.graph.edge_mask),
        )
    st_ref = full_refresh(st_ref, eps=0.1)
    st_sh = sharded_full_refresh(st_sh, mesh=mesh, eps=0.1)
    assert float(st_sh.best_g) == float(st_ref.best_g)
    np.testing.assert_array_equal(
        np.asarray(st_sh.community), np.asarray(st_ref.community)
    )


@multi_device
def test_sharded_delete_matches_single_device():
    """Interleaved inserts + slot-range deletions: the compaction scatter,
    suffix recovery, w0 decrement and community bookkeeping all track the
    single-device engine bit-for-bit (integer weights)."""
    n = 200
    g = random_graph(5, n=n)
    mesh = data_mesh(len(jax.devices()))
    rng = np.random.default_rng(6)
    st_ref = init_state(g, eps=0.1)
    st_sh = init_sharded_state(shard_graph(g, mesh), mesh, eps=0.1)
    for step in range(4):
        B = 64
        bs = jnp.asarray(rng.integers(0, n, B), jnp.int32)
        bd = jnp.asarray(rng.integers(0, n, B), jnp.int32)
        bc = jnp.asarray(rng.integers(1, 4, B), jnp.float32)
        valid = bs != bd
        st_ref = insert_and_maintain(st_ref, bs, bd, bc, valid, eps=0.1)
        st_sh = sharded_insert_and_maintain(
            st_sh, bs, bd, bc, valid, mesh=mesh, eps=0.1
        )
        lo = int(rng.integers(0, 300))
        hi = lo + int(rng.integers(1, 80))
        ids_r = jnp.arange(st_ref.graph.e_capacity, dtype=jnp.int32)
        ids_s = jnp.arange(st_sh.graph.e_capacity, dtype=jnp.int32)
        st_ref = delete_and_maintain(st_ref, (ids_r >= lo) & (ids_r < hi),
                                     eps=0.1)
        st_sh = sharded_delete_and_maintain(
            st_sh, (ids_s >= lo) & (ids_s < hi), mesh=mesh, eps=0.1
        )
        assert float(st_sh.best_g) == float(st_ref.best_g), step
        assert int(st_sh.edge_count) == int(st_ref.edge_count)
        np.testing.assert_array_equal(
            np.asarray(st_sh.level), np.asarray(st_ref.level)
        )
        np.testing.assert_array_equal(
            np.asarray(st_sh.community), np.asarray(st_ref.community)
        )
        np.testing.assert_allclose(np.asarray(st_sh.w0), np.asarray(st_ref.w0))
        E = st_ref.graph.e_capacity
        np.testing.assert_array_equal(
            np.asarray(st_sh.graph.src)[:E], np.asarray(st_ref.graph.src)
        )
        np.testing.assert_array_equal(
            np.asarray(st_sh.graph.edge_mask)[:E],
            np.asarray(st_ref.graph.edge_mask),
        )


@multi_device
def test_device_service_sharded_windowed_matches_single():
    """Sliding-window serving on the mesh: every tick runs expire + insert
    through the psum-reduced engine; final state matches the single-device
    windowed service (DG metric: unit weights, order-robust sums)."""
    from repro.graphstore.generators import make_transaction_stream
    from repro.serve.device_service import run_device_service

    mesh = data_mesh(len(jax.devices()))
    stream = make_transaction_stream(n=800, m=4000, seed=13)
    rep1 = run_device_service(
        stream, metric="DG", batch_edges=128, max_rounds=10, window_ticks=3,
    )
    repn = run_device_service(
        stream, metric="DG", batch_edges=128, max_rounds=10, window_ticks=3,
        mesh=mesh,
    )
    assert repn.final_g == rep1.final_g
    assert repn.live_edges == rep1.live_edges
    assert repn.n_expired_edges == rep1.n_expired_edges
    m_base = stream.base_src.shape[0]
    assert rep1.live_edges <= m_base + 3 * 128


@multi_device
def test_sharded_workset_auto_matches_single_device():
    """Workset ticks on the mesh: per-shard local gather + psum'd workset
    rounds track both the single-device workset engine and the fused
    full-buffer engine bit-for-bit (integer weights), through hot
    (workset) and cold (fallback) ticks alike."""
    n = 200
    g = random_graph(7, n=n)
    mesh = data_mesh(len(jax.devices()))
    rng = np.random.default_rng(8)
    st_ref = init_state(g, eps=0.1)
    st_sh = init_sharded_state(shard_graph(g, mesh), mesh, eps=0.1)
    lv = np.where(np.asarray(g.vertex_mask), np.asarray(st_ref.level), -1)
    hot = np.argsort(lv)[-24:]
    E = st_ref.graph.e_capacity
    took_workset = False
    for step in range(4):
        B = 16
        pool = hot if step % 2 == 0 else np.arange(n)  # hot and cold ticks
        bs = jnp.asarray(rng.choice(pool, B), jnp.int32)
        bd = jnp.asarray(rng.choice(pool, B), jnp.int32)
        bc = jnp.asarray(rng.integers(1, 4, B), jnp.float32)
        valid = bs != bd
        if step == 3:  # one slide tick through the sharded workset path
            drop = jnp.zeros(E, bool).at[jnp.arange(3)].set(True)
            drop_sh = jnp.zeros(st_sh.graph.e_capacity, bool).at[
                jnp.arange(3)
            ].set(True)
            st_ref, i1 = slide_and_maintain_auto(
                st_ref, drop, bs, bd, bc, valid, eps=0.1, min_bucket=8
            )
            st_sh, i2 = sharded_slide_and_maintain_auto(
                st_sh, drop_sh, bs, bd, bc, valid, mesh=mesh, eps=0.1,
                min_bucket=8,
            )
        else:
            st_ref, i1 = insert_and_maintain_auto(
                st_ref, bs, bd, bc, valid, eps=0.1, min_bucket=8
            )
            st_sh, i2 = sharded_insert_and_maintain_auto(
                st_sh, bs, bd, bc, valid, mesh=mesh, eps=0.1, min_bucket=8
            )
        # the suffix is engine-independent; bucket/fallback choices may
        # differ (the sharded engine buckets the max PER-SHARD edge count)
        # yet the results below must still agree bit-for-bit
        assert i1.n_suffix_vertices == i2.n_suffix_vertices, step
        took_workset |= not i1.fallback and not i2.fallback
        assert float(st_sh.best_g) == float(st_ref.best_g), step
        assert int(st_sh.edge_count) == int(st_ref.edge_count)
        np.testing.assert_array_equal(
            np.asarray(st_sh.level), np.asarray(st_ref.level)
        )
        np.testing.assert_array_equal(
            np.asarray(st_sh.community), np.asarray(st_ref.community)
        )
        np.testing.assert_array_equal(
            np.asarray(st_sh.w0), np.asarray(st_ref.w0)
        )
        np.testing.assert_array_equal(
            np.asarray(st_sh.graph.src)[:E], np.asarray(st_ref.graph.src)
        )
    assert took_workset  # the hot ticks must actually exercise the workset


@multi_device
def test_sharded_max_rounds_cutoff_matches():
    g = random_graph(3)
    mesh = data_mesh(len(jax.devices()))
    ref = bulk_peel(g, eps=0.1, max_rounds=3)
    res = sharded_bulk_peel(shard_graph(g, mesh), mesh, eps=0.1, max_rounds=3)
    assert float(res.best_g) == float(ref.best_g)
    np.testing.assert_array_equal(np.asarray(res.level), np.asarray(ref.level))


@multi_device
def test_sharded_peel_requires_divisible_capacity():
    g = random_graph(4, e_slack=3)
    mesh = data_mesh(len(jax.devices()))
    with pytest.raises(ValueError, match="divisible"):
        sharded_bulk_peel(g, mesh)


@multi_device
def test_device_service_sharded_detects_fraud():
    from repro.graphstore.generators import make_transaction_stream
    from repro.serve.device_service import run_device_service

    mesh = data_mesh(len(jax.devices()))
    stream = make_transaction_stream(n=1000, m=5000, seed=11)
    rep = run_device_service(
        stream, metric="DW", batch_edges=256, max_rounds=10,
        refresh_every=2, mesh=mesh,
    )
    assert rep.fraud_recall >= 0.99
    assert rep.final_g > 0
    assert rep.n_refreshes >= 1
