"""Semantics plane + SpadeService facade tests.

Covers: the single registry behind ``make_metric`` and the device seeding
(error messages can't go stale), seed/batch-weight parity of the
registered builtins with the legacy hardcoded formulas, the host adapter,
the facade's engine dispatch (legacy shim equivalence, predictive-selector
equivalence), and the deprecation shims.
"""

from __future__ import annotations

import math
import warnings

import numpy as np
import pytest

import jax.numpy as jnp

from repro._warnings import SpadeDeprecationWarning
from repro.core import Spade
from repro.core.metrics import DensityMetric, make_metric
from repro.core.semantics import (
    DG,
    DW,
    FD,
    SuspSemantics,
    available,
    quantize_susp_array,
    register,
    resolve,
)
from repro.graphstore.generators import make_transaction_stream
from repro.serve import EngineSpec, SpadeService


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------


def test_one_registry_backs_make_metric_and_resolve():
    assert resolve("dg") is DG and resolve("FD") is FD
    assert resolve(DW) is DW
    for name in ("DG", "DW", "FD"):
        assert name in available()
    with pytest.raises(KeyError) as ei:
        make_metric("nope")
    # the message is generated from the live registry, not a literal
    for name in available():
        assert name in str(ei.value)


def test_registered_custom_semantics_reaches_name_lookups():
    custom = SuspSemantics(
        name="TESTREG",
        esusp=lambda xp, s, d, raw, deg, aux: xp.maximum(raw, 1e-12) * 3.0,
    )
    register(custom)
    assert "TESTREG" in available()
    assert resolve("testreg") is custom
    # duplicate registration of a *different* object must be refused
    with pytest.raises(ValueError):
        register(SuspSemantics(name="TESTREG",
                               esusp=lambda xp, s, d, r, g, a: r))
    # the host oracle accepts the name like any builtin
    m = make_metric("TESTREG")
    assert isinstance(m, DensityMetric)
    sp = Spade(metric="TESTREG")
    sp.LoadGraph([0, 1], [1, 2], [2.0, 4.0], n_vertices=3)
    assert sp.graph.adj[0][1] == 6.0
    # ... and the error message now names it
    with pytest.raises(KeyError, match="TESTREG"):
        make_metric("still-unknown")


# ---------------------------------------------------------------------------
# builtin parity with the legacy hardcoded formulas
# ---------------------------------------------------------------------------


def _legacy_seed(metric, src, dst, amt, n, C=5.0):
    from repro.core.semantics import _QUANTUM

    src, dst = np.asarray(src), np.asarray(dst)
    in_deg = np.zeros(n, np.int64)
    np.add.at(in_deg, dst, 1)
    if metric == "DG":
        w = np.ones(src.shape[0], np.float64)
    elif metric == "DW":
        w = np.maximum(np.asarray(amt, np.float64), 1e-12)
    else:
        w = 1.0 / np.log(in_deg[dst] + C)
    return np.maximum(quantize_susp_array(w), _QUANTUM).astype(np.float32), in_deg


@pytest.mark.parametrize("name", ["DG", "DW", "FD"])
def test_seed_base_matches_legacy_formulas(name):
    rng = np.random.default_rng(5)
    n, m = 60, 400
    src = rng.integers(0, n, m)
    dst = rng.integers(0, n, m)
    amt = rng.lognormal(2.0, 1.0, m)
    w_leg, d_leg = _legacy_seed(name, src, dst, amt, n)
    w_new, d_new = resolve(name).seed_base(src, dst, amt, n)
    np.testing.assert_array_equal(w_leg, w_new)
    np.testing.assert_array_equal(d_leg, d_new)


def test_fd_batch_weights_match_host_funnel_at_arrival():
    """Device FD weighting == host FD esusp at arrival time, including
    intra-batch degree evolution — through the semantics API."""
    from repro.core.reference import AdjGraph

    fd_host = make_metric("FD")
    g = AdjGraph(6)
    g.add_edge(0, 2, 1.0)
    g.add_edge(1, 2, 1.0)
    in_deg = jnp.zeros(6, jnp.int32).at[jnp.asarray([2, 2])].add(1)

    batch = [(3, 2, 1.0), (4, 2, 1.0), (0, 5, 1.0)]
    host_w = []
    for u, v, raw in batch:
        host_w.append(fd_host.edge_susp(u, v, raw, g))
        g.add_edge(u, v, raw)
    src = jnp.asarray([b[0] for b in batch], jnp.int32)
    dst = jnp.asarray([b[1] for b in batch], jnp.int32)
    raw = jnp.asarray([b[2] for b in batch], jnp.float32)
    w, new_deg = FD.batch_weights(in_deg, src, dst, raw, jnp.ones(3, bool))
    np.testing.assert_allclose(np.asarray(w), np.asarray(host_w), rtol=1e-6)
    assert int(new_deg[2]) == 4 and int(new_deg[5]) == 1
    assert FD.uses_degree and not DW.uses_degree


def test_vertex_priors_flow_through_seeding_and_host_funnel():
    sem = SuspSemantics(
        name="PRIOR",
        esusp=lambda xp, s, d, raw, deg, aux: xp.ones_like(raw),
        vsusp=lambda xp, ids, deg, aux: (ids % 4) * 1.0,
    )
    a = sem.seed_vertices(8, np.zeros(8, np.int64))
    np.testing.assert_array_equal(a, np.float32([0, 1, 2, 3, 0, 1, 2, 3]))
    m = sem.host_metric()
    from repro.core.reference import AdjGraph

    assert m.vertex_susp(3, AdjGraph(8)) == 3.0
    # DG/DW/FD have no prior: services skip the buffer entirely
    assert DG.seed_vertices(8, np.zeros(8, np.int64)) is None


def test_spade_accepts_semantics_object_like_a_name():
    stream_edges = ([0, 1, 2], [1, 2, 0], [2.0, 3.0, 4.0])
    sp_name = Spade(metric="DW")
    sp_sem = Spade(metric=DW)
    for sp in (sp_name, sp_sem):
        sp.LoadGraph(*stream_edges, n_vertices=3)
    c1, g1 = sp_name.Detect()
    c2, g2 = sp_sem.Detect()
    np.testing.assert_array_equal(c1, c2)
    assert g1 == g2


# ---------------------------------------------------------------------------
# the facade
# ---------------------------------------------------------------------------


def test_engine_spec_validation():
    with pytest.raises(ValueError):
        EngineSpec(plane="gpu")
    with pytest.raises(ValueError):
        EngineSpec(plane="host", window_ticks=4)
    with pytest.raises(ValueError):
        EngineSpec(batch_edges=0)
    # DensityMetric is host-only: device planes need a SuspSemantics
    with pytest.raises(TypeError):
        SpadeService(make_metric("DW"), EngineSpec(plane="device"))


def test_facade_device_matches_legacy_shim_bit_for_bit():
    """The legacy run_device_service shim and the facade drive the same
    loop; on DG (order-robust integer sums) the reports must agree
    exactly, and the shim must warn."""
    from repro.serve.device_service import run_device_service

    stream = make_transaction_stream(n=800, m=4000, seed=21)
    spec = EngineSpec(batch_edges=128, max_rounds=10, window_ticks=2,
                      workset=True, predictive=False, min_bucket=64)
    rep_new = SpadeService("DG", spec).run(stream)
    with pytest.warns(SpadeDeprecationWarning):
        rep_old = run_device_service(
            stream, metric="DG", batch_edges=128, max_rounds=10,
            window_ticks=2, workset=True, min_bucket=64,
        )
    assert rep_new.final_g == rep_old.final_g
    assert rep_new.fraud_recall == rep_old.fraud_recall
    assert rep_new.benign_fraction == rep_old.benign_fraction
    assert rep_new.live_edges == rep_old.live_edges
    assert rep_new.n_workset_ticks == rep_old.n_workset_ticks
    # legacy mode never predicts
    assert rep_old.n_predicted_ticks == 0


def test_predictive_service_matches_synced_service():
    """predictive=True must change only the dispatch mechanics (and the
    telemetry), never the results."""
    stream = make_transaction_stream(n=800, m=4000, seed=22)
    kw = dict(batch_edges=128, max_rounds=10, window_ticks=2, workset=True,
              min_bucket=64)
    rep_sync = SpadeService("DG", EngineSpec(predictive=False, **kw)).run(stream)
    rep_pred = SpadeService("DG", EngineSpec(predictive=True, **kw)).run(stream)
    assert rep_pred.final_g == rep_sync.final_g
    assert rep_pred.fraud_recall == rep_sync.fraud_recall
    assert rep_pred.benign_fraction == rep_sync.benign_fraction
    assert rep_pred.live_edges == rep_sync.live_edges
    # every tick after the first dispatches without a count sync
    assert rep_pred.n_predicted_ticks == rep_pred.n_ticks - 1
    assert rep_sync.n_predicted_ticks == 0
    assert (rep_pred.n_workset_ticks + rep_pred.n_fallback_ticks
            == rep_pred.n_ticks)


def test_facade_host_plane_matches_legacy_run_service():
    from repro.serve.service import run_service

    stream = make_transaction_stream(n=600, m=3000, seed=23)
    spec = EngineSpec(plane="host", grouping=True, batch_edges=1,
                      flush_every=0.5)
    rep_new = SpadeService("DW", spec).run(stream)
    with pytest.warns(SpadeDeprecationWarning):
        rep_old = run_service(stream, metric="DW", edge_grouping=True,
                              batch_size=1, flush_every=0.5)
    assert rep_new.fraud_recall == rep_old.fraud_recall
    assert rep_new.n_reorders == rep_old.n_reorders
    assert rep_new.prevention_ratio == rep_old.prevention_ratio


def test_custom_aux_semantics_runs_through_the_facade():
    """An aux-using (timestamp-decayed) semantics — inexpressible under the
    legacy metric: str API — serves end to end through the device plane."""
    stream = make_transaction_stream(n=600, m=3000, seed=24)
    horizon = float(stream.inc_time.max())
    tau = max(horizon, 1e-6)
    sem = SuspSemantics(
        name="TDECAY-TEST",
        esusp=lambda xp, s, d, raw, deg, t: (
            xp.maximum(raw, 1e-12)
            * 2.0 ** (-(horizon - (0.0 if t is None else t)) / tau)
        ),
        uses_aux=True,
    )
    rep = SpadeService(sem, EngineSpec(batch_edges=256, max_rounds=10,
                                       window_ticks=2)).run(stream)
    assert rep.n_ticks == -(-stream.inc_src.shape[0] // 256)
    assert np.isfinite(rep.final_g) and rep.final_g > 0
    assert rep.fraud_recall > 0


# ---------------------------------------------------------------------------
# deprecation shims
# ---------------------------------------------------------------------------


def test_device_metrics_shims_warn_and_match():
    with pytest.warns(SpadeDeprecationWarning):
        from repro.core.device_metrics import dg_weights

        np.testing.assert_array_equal(
            np.asarray(dg_weights(jnp.asarray([2.0, 5.0]))), [1.0, 1.0]
        )
    with pytest.warns(SpadeDeprecationWarning):
        from repro.core.device_metrics import seed_base_weights

        w, deg = seed_base_weights("FD", [0, 1], [1, 2], [1.0, 1.0], 3)
    w2, deg2 = FD.seed_base([0, 1], [1, 2], [1.0, 1.0], 3)
    np.testing.assert_array_equal(w, w2)
    np.testing.assert_array_equal(deg, deg2)
    assert w[0] == pytest.approx(1.0 / math.log(1 + 5.0), rel=1e-6)
