"""Force 8 XLA host devices so the sharded (dist) paths are exercised.

Must run before jax initializes its backend; conftest import happens
during collection, ahead of every test module.
"""

import os

if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count=8 "
        + os.environ.get("XLA_FLAGS", "")
    ).strip()
