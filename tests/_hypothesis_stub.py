"""Fallback when ``hypothesis`` is absent from the environment: strategy
construction becomes inert and ``@given`` tests skip, so the rest of the
module still runs."""

import pytest


class _AnyStrategy:
    """Absorbs any attribute access / call / chaining (st.lists(...).filter)."""

    def __call__(self, *args, **kwargs):
        return self

    def __getattr__(self, name):
        return self


st = _AnyStrategy()


def settings(*args, **kwargs):
    return lambda fn: fn


def given(*args, **kwargs):
    def deco(fn):
        # must stay a plain named function or pytest drops it from
        # collection instead of reporting a skip
        def _skipped():
            pytest.skip("hypothesis not installed")

        _skipped.__name__ = fn.__name__
        _skipped.__doc__ = fn.__doc__
        return _skipped

    return deco
