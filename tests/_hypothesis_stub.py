"""Deterministic fallback property-test runner for environments without
``hypothesis``.

CI installs the real ``hypothesis`` (see .github/workflows/ci.yml) and the
``try: import hypothesis`` in each test module prefers it; this module only
takes over when the package is absent, so the property tests *run* instead
of skipping.  It implements the small strategy surface the suite uses
(``integers`` / ``floats`` / ``tuples`` / ``lists`` + ``.filter`` /
``.map``) and a ``@given`` that draws ``max_examples`` examples from a PRNG
seeded by the test name — failures therefore replay deterministically: the
failing example index and kwargs are attached to the raised error.

No shrinking, no database, no coverage-guided generation — this is a
fallback, not a hypothesis replacement.
"""

from __future__ import annotations

import zlib

import numpy as np

_DEFAULT_MAX_EXAMPLES = 25
_FILTER_RETRIES = 1000


class _Strategy:
    def example(self, rng: np.random.Generator):
        raise NotImplementedError

    def filter(self, pred):
        return _Filtered(self, pred)

    def map(self, fn):
        return _Mapped(self, fn)


class _Filtered(_Strategy):
    def __init__(self, base, pred):
        self._base, self._pred = base, pred

    def example(self, rng):
        for _ in range(_FILTER_RETRIES):
            x = self._base.example(rng)
            if self._pred(x):
                return x
        raise RuntimeError("filter predicate rejected too many examples")


class _Mapped(_Strategy):
    def __init__(self, base, fn):
        self._base, self._fn = base, fn

    def example(self, rng):
        return self._fn(self._base.example(rng))


class _Integers(_Strategy):
    def __init__(self, lo, hi):
        self._lo, self._hi = int(lo), int(hi)

    def example(self, rng):
        return int(rng.integers(self._lo, self._hi + 1))


class _Floats(_Strategy):
    def __init__(self, lo, hi):
        self._lo, self._hi = float(lo), float(hi)

    def example(self, rng):
        return float(self._lo + (self._hi - self._lo) * rng.random())


class _Tuples(_Strategy):
    def __init__(self, parts):
        self._parts = parts

    def example(self, rng):
        return tuple(p.example(rng) for p in self._parts)


class _Lists(_Strategy):
    def __init__(self, elems, min_size, max_size):
        self._elems = elems
        self._min, self._max = int(min_size), int(max_size)

    def example(self, rng):
        k = int(rng.integers(self._min, self._max + 1))
        return [self._elems.example(rng) for _ in range(k)]


class _Booleans(_Strategy):
    def example(self, rng):
        return bool(rng.integers(0, 2))


class _SampledFrom(_Strategy):
    def __init__(self, options):
        self._options = list(options)

    def example(self, rng):
        return self._options[int(rng.integers(0, len(self._options)))]


class _St:
    """The ``strategies`` namespace (``st.integers(...)``, ...)."""

    @staticmethod
    def integers(min_value=0, max_value=2**31 - 1):
        return _Integers(min_value, max_value)

    @staticmethod
    def floats(min_value=0.0, max_value=1.0, **_):
        return _Floats(min_value, max_value)

    @staticmethod
    def tuples(*parts):
        return _Tuples(parts)

    @staticmethod
    def lists(elements, min_size=0, max_size=10, **_):
        return _Lists(elements, min_size, max_size)

    @staticmethod
    def booleans():
        return _Booleans()

    @staticmethod
    def sampled_from(options):
        return _SampledFrom(options)


st = _St()


def settings(*args, max_examples=_DEFAULT_MAX_EXAMPLES, **kwargs):
    """Attach example-count config; other hypothesis knobs are ignored."""

    def deco(fn):
        fn._stub_max_examples = max_examples
        return fn

    return deco


def given(**strategies):
    def deco(fn):
        def runner():
            n = getattr(runner, "_stub_max_examples", _DEFAULT_MAX_EXAMPLES)
            # seed from the test name: stable across runs and machines
            rng = np.random.default_rng(zlib.crc32(fn.__qualname__.encode()))
            for i in range(n):
                kw = {k: s.example(rng) for k, s in strategies.items()}
                try:
                    fn(**kw)
                except Exception as e:  # replayable failure report
                    raise AssertionError(
                        f"property falsified on example {i} "
                        f"(seed=crc32({fn.__qualname__!r}), deterministic "
                        f"replay: rerun this test): {kw!r}"
                    ) from e

        # pytest must see a zero-arg test (functools.wraps would expose the
        # wrapped signature and turn the draw names into fixture requests)
        runner.__name__ = fn.__name__
        runner.__qualname__ = fn.__qualname__
        runner.__doc__ = fn.__doc__
        runner.__dict__.update(fn.__dict__)
        return runner

    return deco
