"""Exactness tests for the host-plane Spade oracle.

The load-bearing invariant (paper §4 correctness): after any sequence of
incremental ``insert_edges`` calls, the peeling sequence/weights are
*identical* to a from-scratch run of Algorithm 1 on the updated graph.
We verify against an independent naive O(V^2) peel implementation and via
hypothesis property tests with integer weights (exact float arithmetic).
"""

from __future__ import annotations

import itertools

import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container may lack hypothesis; property tests skip
    from _hypothesis_stub import given, settings, st

from repro.core.reference import (
    AdjGraph,
    density_sequence,
    detect,
    insert_edges,
    static_peel,
)

# ---------------------------------------------------------------------------
# independent naive implementation (no heap, no shared code paths)
# ---------------------------------------------------------------------------


def naive_peel(g: AdjGraph):
    n = g.n
    w = g.a[:n].astype(np.float64).copy()
    for u in range(n):
        w[u] += sum(g.adj[u].values())
    remaining = set(range(n))
    order, delta = [], []
    while remaining:
        u = min(remaining, key=lambda x: (w[x], x))
        order.append(u)
        delta.append(w[u])
        remaining.discard(u)
        for v, c in g.adj[u].items():
            if v in remaining:
                w[v] -= c
    return np.array(order), np.array(delta)


def brute_best_density(g: AdjGraph):
    """Exhaustive argmax_g over all non-empty subsets (tiny graphs only)."""
    n = g.n
    best = -1.0
    for r in range(1, n + 1):
        for S in itertools.combinations(range(n), r):
            Sset = set(S)
            f = sum(g.a[u] for u in S)
            for u in S:
                for v, c in g.adj[u].items():
                    if v in Sset and v > u:
                        f += c
                    elif v == u:
                        f += c  # self loop counted once
            best = max(best, f / len(S))
    return best


def random_graph(rng, n, m, int_weights=True, priors=True):
    g = AdjGraph(n)
    if priors:
        g.a[:n] = rng.integers(0, 4, size=n).astype(np.float64)
    edges = []
    for _ in range(m):
        u = int(rng.integers(0, n))
        v = int(rng.integers(0, n))
        if u == v:
            continue
        c = float(rng.integers(1, 6)) if int_weights else float(rng.random() + 0.1)
        g.add_edge(u, v, c)
        edges.append((u, v, c))
    return g, edges


# ---------------------------------------------------------------------------
# static peel
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", range(5))
def test_static_peel_matches_naive(seed):
    rng = np.random.default_rng(seed)
    g, _ = random_graph(rng, n=40, m=120)
    state = static_peel(g.copy())
    o2, d2 = naive_peel(g)
    np.testing.assert_array_equal(state.order(), o2)
    np.testing.assert_allclose(state.delta(), d2)


def test_static_peel_f_consistency():
    rng = np.random.default_rng(0)
    g, _ = random_graph(rng, n=50, m=200)
    state = static_peel(g)
    # sum of peel-time weights == f(V)
    assert np.isclose(state.delta().sum(), g.f_total())


@pytest.mark.parametrize("seed", range(4))
def test_two_approximation(seed):
    rng = np.random.default_rng(100 + seed)
    g, _ = random_graph(rng, n=9, m=20)
    state = static_peel(g.copy())
    _, g_best = detect(state)
    g_star = brute_best_density(g)
    assert g_best >= 0.5 * g_star - 1e-9
    assert g_best <= g_star + 1e-9


def test_detect_matches_density_sequence():
    rng = np.random.default_rng(7)
    g, _ = random_graph(rng, n=30, m=90)
    state = static_peel(g)
    comm, gb = detect(state)
    gseq = density_sequence(state)
    assert np.isclose(gb, gseq.max())
    m = int(np.argmax(gseq))
    np.testing.assert_array_equal(np.sort(comm), np.sort(state.order()[m:]))


# ---------------------------------------------------------------------------
# incremental == from-scratch (the paper's core claim)
# ---------------------------------------------------------------------------


def check_incremental_equals_scratch(n, all_edges, n_base, batch_sizes, priors=None):
    base, inc = all_edges[:n_base], all_edges[n_base:]
    g = AdjGraph(n)
    if priors is not None:
        g.a[:n] = priors
    for u, v, c in base:
        g.add_edge(u, v, c)
    state = static_peel(g)

    i = 0
    for b in itertools.cycle(batch_sizes):
        if i >= len(inc):
            break
        batch = inc[i : i + b]
        i += b
        insert_edges(state, batch)

    full = AdjGraph(n)
    if priors is not None:
        full.a[:n] = priors
    for u, v, c in all_edges:
        full.add_edge(u, v, c)
    expect = static_peel(full)

    np.testing.assert_array_equal(state.order(), expect.order())
    np.testing.assert_allclose(state.delta(), expect.delta())
    c1, g1 = detect(state)
    c2, g2 = detect(expect)
    assert np.isclose(g1, g2)
    np.testing.assert_array_equal(np.sort(c1), np.sort(c2))


@pytest.mark.parametrize("seed,batch", [(s, b) for s in range(6) for b in (1, 3, 7)])
def test_incremental_random(seed, batch):
    rng = np.random.default_rng(seed)
    n, m = 35, 140
    _, edges = random_graph(rng, n, m)
    priors = rng.integers(0, 3, size=n).astype(np.float64)
    check_incremental_equals_scratch(n, edges, int(len(edges) * 0.6), [batch], priors)


def test_incremental_dense_community_emerges():
    """Inject a dense block via increments; detection must converge to it."""
    rng = np.random.default_rng(42)
    n = 60
    g = AdjGraph(n)
    edges = []
    for _ in range(80):  # sparse background
        u, v = rng.integers(0, n, 2)
        if u != v:
            c = float(rng.integers(1, 3))
            g.add_edge(int(u), int(v), c)
            edges.append((int(u), int(v), c))
    state = static_peel(g)
    block = list(range(10))  # fraudsters 0..9, fully connected heavy edges
    for u in block:
        for v in block:
            if u < v:
                insert_edges(state, [(u, v, 10.0)])
    comm, gb = detect(state)
    assert set(block).issubset(set(comm.tolist()))
    # cross-check against scratch
    expect = static_peel(state.graph.copy())
    np.testing.assert_array_equal(state.order(), expect.order())


def test_incremental_with_new_vertices():
    rng = np.random.default_rng(3)
    n = 20
    g, edges = random_graph(rng, n, 50)
    state = static_peel(g)
    # two new vertices joining with edges (dense ids)
    insert_edges(state, [(20, 5, 4.0)], new_vertices=[(20, 1.0)])
    insert_edges(state, [(21, 20, 2.0), (3, 21, 7.0)], new_vertices=[(21, 0.0)])
    expect = static_peel(state.graph.copy())
    np.testing.assert_array_equal(state.order(), expect.order())
    np.testing.assert_allclose(state.delta(), expect.delta())


def test_insert_between_far_apart_positions():
    """Edge between the first-peeled and last-peeled vertices."""
    rng = np.random.default_rng(11)
    g, _ = random_graph(rng, 30, 80)
    state = static_peel(g)
    first, last = int(state.order()[0]), int(state.order()[-1])
    insert_edges(state, [(first, last, 3.0)])
    expect = static_peel(state.graph.copy())
    np.testing.assert_array_equal(state.order(), expect.order())


def test_parallel_edge_accumulation():
    g = AdjGraph(3)
    g.add_edge(0, 1, 1.0)
    state = static_peel(g)
    insert_edges(state, [(0, 1, 2.0), (1, 0, 1.0)])  # multi-edges both ways
    assert state.graph.adj[0][1] == 4.0
    expect = static_peel(state.graph.copy())
    np.testing.assert_array_equal(state.order(), expect.order())


# ---------------------------------------------------------------------------
# hypothesis property tests
# ---------------------------------------------------------------------------

edge_strategy = st.tuples(
    st.integers(0, 11), st.integers(0, 11), st.integers(1, 5)
).filter(lambda e: e[0] != e[1])


@settings(max_examples=60, deadline=None)
@given(
    edges=st.lists(edge_strategy, min_size=1, max_size=40),
    split=st.floats(0.0, 1.0),
    batch=st.integers(1, 5),
    priors=st.lists(st.integers(0, 3), min_size=12, max_size=12),
)
def test_property_incremental_equals_scratch(edges, split, batch, priors):
    n = 12
    all_edges = [(u, v, float(c)) for u, v, c in edges]
    n_base = int(len(all_edges) * split)
    check_incremental_equals_scratch(
        n, all_edges, n_base, [batch], np.array(priors, dtype=np.float64)
    )


@settings(max_examples=30, deadline=None)
@given(
    edges=st.lists(edge_strategy, min_size=2, max_size=30),
    k=st.integers(1, 6),
)
def test_property_affected_area_bounded(edges, k):
    """|V_T| never exceeds |V|; reorder stats are sane."""
    n = 12
    all_edges = [(u, v, float(c)) for u, v, c in edges]
    g = AdjGraph(n)
    base, tail = all_edges[:-k] or all_edges[:1], all_edges[-k:]
    for u, v, c in base:
        g.add_edge(u, v, c)
    state = static_peel(g)
    stats = insert_edges(state, tail)
    assert stats.n_pending <= n + stats.n_new_vertices
    assert stats.n_inserted_edges == len(tail)
