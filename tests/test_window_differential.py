"""Property-based differential harness for sliding-window maintenance.

Random interleavings of inserts, deletes, and window slides are replayed
through the incremental engines and checked against from-scratch oracles:

* **host plane** — ``insert_edges`` / ``delete_edge`` on a ``PeelState``
  must reproduce ``static_peel`` of the maintained graph *exactly*
  (order and peel-time weights) after every operation; ``Spade`` with
  edge grouping must do the same at every flush point.
* **device plane** — the windowed replay (the fused ``slide_and_maintain``
  service tick alternated with composed ``delete_and_maintain`` +
  ``insert_and_maintain``, under the service's slot bookkeeping) must track
  the host-mirrored window edge multiset and ``w0`` bit-exactly (integer
  weights), report a community whose density upper-bounds ``best_g`` and
  never exceed the brute-forced optimal density; a final ``full_refresh``
  must coincide with a from-scratch ``bulk_peel`` of the surviving graph.
  (Community *membership* parity with the host is not expected: the
  device plane is the 2(1+eps)-approximate bulk engine.)

Integer weights keep every float32/float64 sum exact, so all equality
checks are bit-level, and ``derandomize=True`` pins hypothesis to
deterministic example sequences — failures replay by rerunning the test.
The ``_hypothesis_stub`` fallback runner is seeded by test name and is
deterministic by construction.
"""

from __future__ import annotations

import itertools

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container may lack hypothesis; stub runner takes over
    from _hypothesis_stub import given, settings, st

import jax.numpy as jnp

from repro.core.incremental import (
    delete_and_maintain,
    full_refresh,
    init_state,
    insert_and_maintain,
    insert_and_maintain_auto,
    slide_and_maintain,
    slide_and_maintain_auto,
)
from repro.core.peel import (
    bulk_peel,
    bulk_peel_warm,
    bulk_peel_warm_workset,
    select_bucket,
    workset_sizes,
)
from repro.core.reference import (
    AdjGraph,
    delete_edge,
    detect,
    insert_edges,
    peeling_weights_full,
    static_peel,
)
from repro.core.spade import Spade
from repro.graphstore.structs import device_graph_from_coo

N = 10  # vertex universe: small enough to brute-force optimal density
V_CAP, E_CAP = 16, 96  # fixed capacities -> one jit compilation per engine
EPS = 0.1

edge_st = st.tuples(
    st.integers(0, N - 1), st.integers(0, N - 1), st.integers(1, 5)
).filter(lambda e: e[0] != e[1])


def build_host(edges):
    g = AdjGraph(N)
    for u, v, c in edges:
        g.add_edge(int(u), int(v), float(c))
    return g


def brute_best_density(edges) -> float:
    """Exhaustive argmax_g over all non-empty subsets (a = 0)."""
    best = 0.0
    for r in range(1, N + 1):
        for S in itertools.combinations(range(N), r):
            Sset = set(S)
            f = sum(c for u, v, c in edges if u in Sset and v in Sset)
            best = max(best, f / r)
    return best


def exact_density(edges, members) -> float:
    mset = set(int(x) for x in members)
    if not mset:
        return 0.0
    f = sum(c for u, v, c in edges if u in mset and v in mset)
    return f / len(mset)


def live_edge_multiset(state):
    em = np.asarray(state.graph.edge_mask)
    return sorted(
        zip(
            np.asarray(state.graph.src)[em].tolist(),
            np.asarray(state.graph.dst)[em].tolist(),
            np.asarray(state.graph.c)[em].tolist(),
        )
    )


# ---------------------------------------------------------------------------
# host plane: interleaved insert/delete == scratch, after every op
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None, derandomize=True)
@given(
    base=st.lists(edge_st, min_size=2, max_size=15),
    ops=st.lists(
        st.tuples(st.booleans(), edge_st, st.integers(0, 10**6)),
        min_size=1,
        max_size=12,
    ),
)
def test_property_host_interleaved_insert_delete(base, ops):
    """(is_insert, edge, pick): inserts add the edge; deletes remove the
    pick-th live combined edge.  Exact order/delta equality with a scratch
    peel must hold after *every* operation (paper §4 + Appendix C.1)."""
    g = build_host(base)
    state = static_peel(g)
    live = list(base)
    for is_insert, (u, v, c), pick in ops:
        if is_insert or not live:
            insert_edges(state, [(u, v, float(c))])
            live.append((u, v, c))
        else:
            du, dv, _ = live[pick % len(live)]
            if dv not in state.graph.adj[du]:
                continue  # already fully removed via a combined deletion
            delete_edge(state, du, dv)  # removes the whole combined weight
            live = [e for e in live if set(e[:2]) != {du, dv}]
        expect = static_peel(state.graph.copy())
        np.testing.assert_array_equal(state.order(), expect.order())
        np.testing.assert_allclose(state.delta(), expect.delta())


@settings(max_examples=20, deadline=None, derandomize=True)
@given(
    base=st.lists(edge_st, min_size=3, max_size=15),
    batches=st.lists(
        st.lists(edge_st, min_size=1, max_size=4), min_size=1, max_size=4
    ),
    metric=st.sampled_from(["DG", "DW"]),
)
def test_property_spade_grouping_flush_interleaving(base, batches, metric):
    """Spade with edge grouping: after every forced flush the maintained
    state equals a scratch peel of the maintained graph."""
    sp = Spade(metric=metric, edge_grouping=True)
    src = [e[0] for e in base]
    dst = [e[1] for e in base]
    w = [float(e[2]) for e in base]
    sp.LoadGraph(src, dst, w, n_vertices=N)
    for batch in batches:
        sp.InsertBatchEdges([(u, v, float(c)) for u, v, c in batch])
        sp.FlushBuffer()
        expect = static_peel(sp.graph.copy())
        np.testing.assert_array_equal(sp.state.order(), expect.order())
        np.testing.assert_allclose(sp.state.delta(), expect.delta())


# ---------------------------------------------------------------------------
# device plane: windowed replay vs host mirror + scratch oracles
# ---------------------------------------------------------------------------


def assert_states_bit_identical(a, b, tag=""):
    """Full-state bit equality (integer weights keep every sum exact)."""
    for f in ("level", "best_g", "community", "edge_count", "w0"):
        np.testing.assert_array_equal(
            np.asarray(getattr(a, f)), np.asarray(getattr(b, f)),
            err_msg=f"{tag}:{f}",
        )
    for f in ("src", "dst", "c", "edge_mask"):
        np.testing.assert_array_equal(
            np.asarray(getattr(a.graph, f)), np.asarray(getattr(b.graph, f)),
            err_msg=f"{tag}:graph.{f}",
        )


def run_window_differential(base, ticks, window):
    """Replay ``ticks`` batches through the device engine with an
    N-tick sliding window, mirroring the service's slot bookkeeping, and
    check the full invariant set against host oracles after every tick.

    A twin state runs every tick through the **workset engine**
    (``*_and_maintain_auto``: gather the affected suffix into bucketed
    buffers, re-peel the workset only, scatter back — with automatic
    full-buffer fallback) and must stay bit-identical to the fused
    full-buffer path; the tiny ``min_bucket`` makes the replay cross
    bucket boundaries and exercise workset and fallback ticks alike."""
    B = 4  # fixed padded batch size -> stable jit shapes
    src = np.array([e[0] for e in base], np.int64)
    dst = np.array([e[1] for e in base], np.int64)
    c = np.array([e[2] for e in base], np.float32)
    mk = lambda: device_graph_from_coo(
        N, src, dst, c, n_capacity=V_CAP, e_capacity=E_CAP
    )
    state = init_state(mk(), eps=EPS)
    state_ws = init_state(mk(), eps=EPS)  # independent buffers (donation)
    m_base = len(base)
    ring: list[list[tuple[int, int, int]]] = []
    slot_ids = jnp.arange(E_CAP, dtype=jnp.int32)
    zi = jnp.zeros(B, jnp.int32)
    zf = jnp.zeros(B, jnp.float32)
    zv = jnp.zeros(B, bool)

    for t, batch in enumerate(ticks):
        n_exp = len(ring.pop(0)) if len(ring) >= window else 0
        drop = (slot_ids >= m_base) & (slot_ids < m_base + n_exp)
        bs = np.zeros(B, np.int32)
        bd = np.zeros(B, np.int32)
        bc = np.zeros(B, np.float32)
        valid = np.zeros(B, bool)
        for k, (u, v, w) in enumerate(batch):
            bs[k], bd[k], bc[k], valid[k] = u, v, w, True
        bs, bd = jnp.asarray(bs), jnp.asarray(bd)
        bc, valid = jnp.asarray(bc), jnp.asarray(valid)
        # alternate the fused service tick and the composed ops so both
        # maintenance paths face the same oracle
        if t % 2 == 0:
            state = slide_and_maintain(state, drop, bs, bd, bc, valid, eps=EPS)
            state_ws, _ = slide_and_maintain_auto(
                state_ws, drop, bs, bd, bc, valid, eps=EPS, min_bucket=4
            )
        else:
            state = delete_and_maintain(state, drop, eps=EPS)
            state = insert_and_maintain(state, bs, bd, bc, valid, eps=EPS)
            state_ws, _ = slide_and_maintain_auto(  # pure-deletion twin
                state_ws, drop, zi, zi, zf, zv, eps=EPS, min_bucket=4
            )
            state_ws, _ = insert_and_maintain_auto(
                state_ws, bs, bd, bc, valid, eps=EPS, min_bucket=4
            )
        assert_states_bit_identical(state, state_ws, tag=f"tick{t}")
        ring.append(list(batch))

        mirror = list(base) + [e for b in ring for e in b]
        # 1. graph content parity with the host-mirrored window (exact)
        assert live_edge_multiset(state) == sorted(
            (u, v, float(w)) for u, v, w in mirror
        )
        assert int(state.edge_count) == len(mirror)
        # 2. w0 == host full-graph peeling weights (exact integer sums)
        host = build_host(mirror)
        np.testing.assert_array_equal(
            np.asarray(state.w0)[:N], peeling_weights_full(host)
        )
        # 3. density bookkeeping: best_g is conservative (never above the
        #    reported community's exact density, never above the optimum)
        comm = np.where(np.asarray(state.community))[0]
        assert comm.size > 0
        g_comm = exact_density(mirror, comm)
        g_star = brute_best_density(mirror)
        assert float(state.best_g) <= g_comm + 1e-4
        assert float(state.best_g) <= g_star + 1e-4

    # 4. refresh differential: a from-scratch bulk peel of the surviving
    #    buffers must coincide with the refreshed state (level bit-parity),
    #    and the refreshed best carries the bulk 2(1+eps) guarantee.
    mirror = list(base) + [e for b in ring for e in b]
    refreshed = full_refresh(state, eps=EPS)
    scratch = bulk_peel(state.graph, eps=EPS)
    np.testing.assert_array_equal(
        np.asarray(refreshed.level), np.asarray(scratch.level)
    )
    assert float(refreshed.best_g) == float(scratch.best_g)
    _, g_seq = detect(static_peel(build_host(mirror)))
    assert float(refreshed.best_g) >= g_seq / (2.0 * (1.0 + EPS)) - 1e-4
    return state


@settings(max_examples=15, deadline=None, derandomize=True)
@given(
    base=st.lists(edge_st, min_size=2, max_size=10),
    ticks=st.lists(
        st.lists(edge_st, min_size=0, max_size=4), min_size=1, max_size=6
    ),
    window=st.integers(1, 3),
)
def test_property_device_window_differential(base, ticks, window):
    run_window_differential(base, ticks, window)


@pytest.mark.parametrize("seed", range(3))
def test_window_replay_seeded(seed):
    """Non-property twin with pinned seeds: always runs, regardless of
    which property runner is active."""
    rng = np.random.default_rng(100 + seed)

    def rand_edges(k):
        out = []
        for _ in range(k):
            u, v = rng.integers(0, N, 2)
            if u != v:
                out.append((int(u), int(v), int(rng.integers(1, 6))))
        return out

    base = rand_edges(12) or [(0, 1, 2)]
    ticks = [rand_edges(int(rng.integers(1, 5))) for _ in range(6)]
    state = run_window_differential(base, ticks, window=2)
    # window bound: only base + at most 2 ticks of <=4 edges remain
    assert int(state.edge_count) <= len(base) + 2 * 4


# ---------------------------------------------------------------------------
# workset warm peel: bit-parity across bucket-boundary suffix sizes
# ---------------------------------------------------------------------------

FLOOR = 8  # tiny bucket floor so the boundaries are cheap to cross


def _boundary_graph():
    """Integer-weight graph big enough for non-trivial suffixes."""
    rng = np.random.default_rng(77)
    n, m = 120, 500
    src = rng.integers(0, n, m)
    dst = rng.integers(0, n, m)
    keep = src != dst
    c = rng.integers(1, 6, keep.sum()).astype(np.float32)
    return device_graph_from_coo(
        n, src[keep], dst[keep], c, n_capacity=128, e_capacity=1024
    )


@pytest.mark.parametrize("kn", [0, 1, FLOOR - 1, FLOOR, FLOOR + 1, 40])
def test_workset_warm_peel_bucket_boundaries(kn):
    """Suffix sizes straddling a bucket boundary (empty, 1, bucket-1,
    bucket, bucket+1, several buckets): the workset warm peel must match
    the full-buffer warm peel bit-for-bit on integer weights — level (on
    the kept suffix and as the full scattered vector), best density,
    best level, and round count."""
    g = _boundary_graph()
    res0 = bulk_peel(g, eps=EPS)
    lv = np.where(np.asarray(g.vertex_mask), np.asarray(res0.level), -1)
    top = np.argsort(lv)[-kn:] if kn else np.empty(0, np.int64)
    keep = jnp.zeros(g.n_capacity, bool).at[jnp.asarray(top, jnp.int32)].set(
        True, mode="drop"
    )
    nv, ne = workset_sizes(g, keep)
    bv = select_bucket(int(nv), g.n_capacity, floor=FLOOR)
    be = select_bucket(int(ne), g.e_capacity, floor=FLOOR)
    assert bv is not None and be is not None
    full = bulk_peel_warm(g, keep, prior_best_g=res0.best_g, eps=EPS)
    ws = bulk_peel_warm_workset(
        g, keep, prior_best_g=res0.best_g, eps=EPS, v_bucket=bv, e_bucket=be
    )
    np.testing.assert_array_equal(np.asarray(full.level), np.asarray(ws.level))
    assert float(full.best_g) == float(ws.best_g)
    assert int(full.best_level) == int(ws.best_level)
    assert int(full.n_rounds) == int(ws.n_rounds)


def test_select_bucket_ladder_and_fallback_threshold():
    """The ladder rounds up to powers of two from the floor; counts above
    the largest bucket (largest power of two <= capacity/2) return None."""
    assert select_bucket(0, 1024, floor=8) == 8
    assert select_bucket(1, 1024, floor=8) == 8
    assert select_bucket(8, 1024, floor=8) == 8
    assert select_bucket(9, 1024, floor=8) == 16
    assert select_bucket(511, 1024, floor=8) == 512
    assert select_bucket(512, 1024, floor=8) == 512  # largest bucket
    assert select_bucket(513, 1024, floor=8) is None  # > largest -> fallback
    with pytest.raises(ValueError):
        select_bucket(-1, 1024)


def test_auto_dispatch_falls_back_beyond_largest_bucket():
    """A batch touching level-0 vertices drags the whole graph into the
    suffix: the auto engine must take the full-buffer fallback and still
    match the fused path bit-for-bit."""
    g1, g2 = _boundary_graph(), _boundary_graph()
    s_full = init_state(g1, eps=EPS)
    s_auto = init_state(g2, eps=EPS)
    lv = np.where(np.asarray(g1.vertex_mask), np.asarray(s_full.level), 99)
    cold = np.argsort(lv)[:8]  # lowest-level endpoints -> maximal suffix
    bs = jnp.asarray(cold[:4], jnp.int32)
    bd = jnp.asarray(cold[4:], jnp.int32)
    bc = jnp.ones(4, jnp.float32)
    valid = bs != bd
    s_full = insert_and_maintain(s_full, bs, bd, bc, valid, eps=EPS)
    s_auto, info = insert_and_maintain_auto(
        s_auto, bs, bd, bc, valid, eps=EPS, min_bucket=FLOOR
    )
    assert info.fallback
    assert info.v_bucket == 0 and info.e_bucket == 0
    # the suffix swallowed (nearly) the whole vertex set, past the largest
    # vertex bucket (largest power of two <= n_capacity/2)
    assert info.n_suffix_vertices > g1.n_capacity // 2
    assert_states_bit_identical(s_full, s_auto, tag="fallback")


def test_predictive_dispatch_matches_fused_across_hit_miss_fallback():
    """The predictive selector (buckets from the previous tick's counts,
    device-side fit check) must stay bit-identical to the fused path on
    a tick mix that covers: the synced first tick, predicted workset hits,
    a bucket miss (suffix outgrows the prediction -> in-program fallback),
    and re-anchoring after the miss."""
    from repro.core.incremental import (
        BucketPredictor,
        insert_and_maintain_predictive,
    )

    g1, g2 = _boundary_graph(), _boundary_graph()
    s_full = init_state(g1, eps=EPS)
    s_pred = init_state(g2, eps=EPS)
    predictor = BucketPredictor(g1.n_capacity, g1.e_capacity,
                                min_bucket=FLOOR)
    lv = np.where(np.asarray(g1.vertex_mask), np.asarray(s_full.level), -1)
    hot = np.argsort(lv)[-8:]
    cold = np.argsort(np.where(np.asarray(g1.vertex_mask),
                               np.asarray(s_full.level), 99))[:8]
    saw_predicted = saw_miss = False
    for t, ids in enumerate([hot, hot, cold, hot, hot]):
        bs = jnp.asarray(ids[:4], jnp.int32)
        bd = jnp.asarray(ids[4:], jnp.int32)
        bc = jnp.full(4, float(t + 1), jnp.float32)
        valid = bs != bd
        s_full = insert_and_maintain(s_full, bs, bd, bc, valid, eps=EPS)
        s_pred, info = insert_and_maintain_predictive(
            s_pred, bs, bd, bc, valid, predictor, eps=EPS
        )
        saw_predicted |= info.predicted
        saw_miss |= info.miss
        assert_states_bit_identical(s_full, s_pred, tag=f"pred-tick{t}")
    assert saw_predicted and saw_miss


def test_auto_dispatch_hot_suffix_takes_workset_path():
    """A batch confined to the highest-level vertices keeps the suffix
    small: the auto engine must take the workset path (no fallback) and
    match the fused path bit-for-bit."""
    g1, g2 = _boundary_graph(), _boundary_graph()
    s_full = init_state(g1, eps=EPS)
    s_auto = init_state(g2, eps=EPS)
    lv = np.where(np.asarray(g1.vertex_mask), np.asarray(s_full.level), -1)
    hot = np.argsort(lv)[-8:]
    bs = jnp.asarray(hot[:4], jnp.int32)
    bd = jnp.asarray(hot[4:], jnp.int32)
    bc = jnp.ones(4, jnp.float32)
    valid = bs != bd
    s_full = insert_and_maintain(s_full, bs, bd, bc, valid, eps=EPS)
    s_auto, info = insert_and_maintain_auto(
        s_auto, bs, bd, bc, valid, eps=EPS, min_bucket=FLOOR
    )
    assert not info.fallback
    assert info.e_bucket >= FLOOR
    assert_states_bit_identical(s_full, s_auto, tag="hot")


# ---------------------------------------------------------------------------
# pluggable semantics: a user-defined (non-builtin) SuspSemantics must reach
# every engine with no engine-file edits, bit-identically on integer weights
# ---------------------------------------------------------------------------

from repro.core.semantics import SuspSemantics  # noqa: E402

# parity-boost semantics: odd src+dst doubles the amount; vertex prior
# id % 3.  Integer-valued on integer inputs, so every f32/f64 sum is exact
# and cross-plane equality is bit-level — and it is *not* DG/DW/FD.
PARITY_SEM = SuspSemantics(
    name="XPARITY",
    esusp=lambda xp, src, dst, raw, deg, aux: raw * (1.0 + (src + dst) % 2),
    vsusp=lambda xp, ids, deg, aux: (ids % 3) * 1.0,
)


def _brute_best_density_weighted(edges, a) -> float:
    """Exhaustive argmax_g with vertex priors (f = Σa + Σc)."""
    best = 0.0
    for r in range(1, N + 1):
        for S in itertools.combinations(range(N), r):
            Sset = set(S)
            f = sum(float(a[u]) for u in Sset)
            f += sum(c for u, v, c in edges if u in Sset and v in Sset)
            best = max(best, f / r)
    return best


def test_custom_semantics_cross_plane_differential():
    """Per-tick bit-equality of a user-defined semantics across the host
    oracle, single-device fused, single-device workset, and mesh-sharded
    workset engines (acceptance criterion of the semantics-plane redesign).

    The host oracle (``Spade`` maintained incrementally through the same
    semantics, expiry via ``DeleteEdge``) pins the exact invariants the
    device planes must track bit-for-bit on integer weights: the window's
    edge multiset (through the host funnel's weighting), ``w0`` with
    vertex priors included, and a conservative ``best_g``.  The three
    device engines must agree on the *full state* — community included —
    among themselves (community parity with the exact oracle is not
    expected from the 2(1+eps) bulk engine)."""
    import jax

    from repro.core.incremental import insert_and_maintain_auto as _ins_auto
    from repro.core.incremental import slide_and_maintain_auto as _sl_auto
    from repro.dist.graph import (
        init_sharded_state,
        shard_graph,
        sharded_insert_and_maintain_auto,
        sharded_slide_and_maintain,
    )

    if len(jax.devices()) < 8:
        pytest.skip("needs 8 (forced host) devices")
    mesh = jax.make_mesh((8,), ("data",))
    sem = PARITY_SEM
    rng = np.random.default_rng(1234)

    def rand_batch(k):
        out = []
        for _ in range(k):
            u, v = (int(x) for x in rng.integers(0, N, 2))
            if u != v:
                out.append((u, v, int(rng.integers(1, 6))))
        return out

    base = rand_batch(12) or [(0, 1, 2)]
    ticks = [rand_batch(int(rng.integers(1, 4))) for _ in range(6)]
    window = 2
    B = 4

    src = np.array([e[0] for e in base], np.int64)
    dst = np.array([e[1] for e in base], np.int64)
    amt = np.array([e[2] for e in base], np.float64)

    # device seeding through the semantics' batch-seeding rule (the API —
    # no engine knows the semantics' name)
    base_w, in_deg = sem.seed_base(src, dst, amt, N)
    a0 = sem.seed_vertices(N, in_deg)
    mk = lambda: device_graph_from_coo(
        N, src, dst, base_w, a=a0, n_capacity=V_CAP, e_capacity=E_CAP
    )
    state = init_state(mk(), eps=EPS)
    state_ws = init_state(mk(), eps=EPS)
    state_sh = init_sharded_state(shard_graph(mk(), mesh), mesh, eps=EPS)

    # host oracle through the identical semantics (funnel-compiled)
    sp = Spade(metric=sem)
    sp.LoadGraph(src, dst, amt, n_vertices=N)
    m = sp.metric

    weight_fn = jax.jit(sem.batch_weights)
    deg_dev = jnp.zeros(V_CAP, jnp.int32).at[: N].set(
        jnp.asarray(in_deg, jnp.int32)
    )
    m_base = len(base)
    ring: list[list[tuple[int, int, float]]] = []
    slot_ids = jnp.arange(E_CAP, dtype=jnp.int32)

    for t, batch in enumerate(ticks):
        expired = ring.pop(0) if len(ring) >= window else []
        drop = (slot_ids >= m_base) & (slot_ids < m_base + len(expired))
        bs = np.zeros(B, np.int32)
        bd = np.zeros(B, np.int32)
        raw = np.zeros(B, np.float32)
        valid = np.zeros(B, bool)
        for k, (u, v, r) in enumerate(batch):
            bs[k], bd[k], raw[k], valid[k] = u, v, r, True
        bs_d, bd_d = jnp.asarray(bs), jnp.asarray(bd)
        valid_d = jnp.asarray(valid)
        w, deg_dev = weight_fn(deg_dev, bs_d, bd_d, jnp.asarray(raw), valid_d)

        # host-funnel weights must equal the device weights bit-for-bit
        host_w = [m.edge_susp(u, v, float(r), sp.graph) for u, v, r in batch]
        np.testing.assert_array_equal(
            np.asarray(w)[: len(batch)], np.asarray(host_w, np.float32)
        )

        # the three device engines take the identical tick
        state = slide_and_maintain(state, drop, bs_d, bd_d, w, valid_d, eps=EPS)
        state_ws, _ = _sl_auto(state_ws, drop, bs_d, bd_d, w, valid_d,
                               eps=EPS, min_bucket=4)
        state_sh = sharded_slide_and_maintain(
            state_sh, drop, bs_d, bd_d, w, valid_d, mesh=mesh, eps=EPS
        )
        assert_states_bit_identical(state, state_ws, tag=f"sem-ws-tick{t}")
        assert_states_bit_identical(state, state_sh, tag=f"sem-sh-tick{t}")

        # host oracle: insert the batch, expire the window's oldest batch
        sp.InsertBatchEdges([(u, v, float(r)) for u, v, r in batch])
        for u, v, c in expired:
            sp.DeleteEdge(u, v, c)
        ring.append([(u, v, float(cw)) for (u, v, _), cw in zip(batch, host_w)])

        mirror = [(u, v, float(c)) for (u, v), c in zip(
            zip(src.tolist(), dst.tolist()), base_w.tolist())]
        mirror += [e for b in ring for e in b]
        # 1. window edge-multiset parity with the host mirror (exact)
        assert live_edge_multiset(state) == sorted(mirror)
        # 2. w0 (priors included) == host full-graph peeling weights
        np.testing.assert_array_equal(
            np.asarray(state.w0)[:N], peeling_weights_full(sp.graph)[:N]
        )
        # 3. conservative density bookkeeping under the custom semantics
        comm = np.where(np.asarray(state.community))[0]
        assert comm.size > 0
        g_comm = (sum(float(a0[u]) for u in comm)
                  + sum(c for u, v, c in mirror
                        if u in set(comm) and v in set(comm))) / comm.size
        assert float(state.best_g) <= g_comm + 1e-4
        assert float(state.best_g) <= _brute_best_density_weighted(
            mirror, a0) + 1e-4

    # insert-only twin parity through the auto (workset) engines as well,
    # sharded included
    bs = jnp.asarray([0, 1, 2, 3], jnp.int32)
    bd = jnp.asarray([4, 5, 6, 7], jnp.int32)
    raw = jnp.asarray([2.0, 3.0, 1.0, 4.0], jnp.float32)
    valid = jnp.ones(4, bool)
    w, deg_dev = weight_fn(deg_dev, bs, bd, raw, valid)
    state = insert_and_maintain(state, bs, bd, w, valid, eps=EPS)
    state_ws, _ = _ins_auto(state_ws, bs, bd, w, valid, eps=EPS, min_bucket=4)
    state_sh, _ = sharded_insert_and_maintain_auto(
        state_sh, bs, bd, w, valid, mesh=mesh, eps=EPS, min_bucket=4
    )
    assert_states_bit_identical(state, state_ws, tag="sem-final-insert-ws")
    assert_states_bit_identical(state, state_sh, tag="sem-final-insert-sh")

    # 4. refresh differential: scratch bulk peel of the survivors agrees
    refreshed = full_refresh(state, eps=EPS)
    scratch = bulk_peel(state.graph, eps=EPS)
    np.testing.assert_array_equal(
        np.asarray(refreshed.level), np.asarray(scratch.level)
    )
    assert float(refreshed.best_g) == float(scratch.best_g)
