"""End-to-end system tests: streaming service detection, cross-plane
(host oracle vs device bulk) consistency, sampler integration, and a
subprocess dry-run of one full-size cell."""

from __future__ import annotations

import os
import subprocess
import sys

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.incremental import init_state, insert_and_maintain
from repro.core.reference import detect, static_peel
from repro.core.spade import Spade
from repro.graphstore.generators import make_transaction_stream
from repro.graphstore.sampler import build_csr_neighbors, sample_fanout
from repro.graphstore.structs import device_graph_from_coo
from repro.serve.service import run_service

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_service_detects_planted_fraud_with_grouping():
    stream = make_transaction_stream(n=4000, m=20000, seed=5)
    rep = run_service(stream, metric="DW", edge_grouping=True, batch_size=1,
                      flush_every=0.5)
    assert rep.fraud_recall >= 0.99
    assert rep.prevention_ratio is not None and rep.prevention_ratio > 0.5
    assert rep.n_reorders < rep.n_edges  # grouping actually buffered


def test_service_batching_policies_agree_on_final_state():
    """Different batching policies must converge to the same final graph and
    (hence) the same community."""
    finals = []
    for kwargs in (dict(edge_grouping=False, batch_size=1),
                   dict(edge_grouping=False, batch_size=100),
                   dict(edge_grouping=True, batch_size=1)):
        stream = make_transaction_stream(n=2000, m=10000, seed=6)
        sp = Spade(metric="DW", edge_grouping=kwargs.get("edge_grouping", False))
        sp.LoadGraph(stream.base_src, stream.base_dst, stream.base_amt,
                     n_vertices=stream.n_vertices)
        edges = list(zip(stream.inc_src.tolist(), stream.inc_dst.tolist(),
                         stream.inc_amt.tolist()))
        b = kwargs["batch_size"]
        for i in range(0, len(edges), b):
            sp.InsertBatchEdges(edges[i : i + b])
        sp.FlushBuffer()
        comm, g = sp.Detect()
        finals.append((tuple(sorted(comm.tolist())), round(g, 6)))
    assert finals[0] == finals[1] == finals[2]


def test_cross_plane_consistency():
    """Host exact peel vs device bulk peel on the same evolving graph: the
    device community's density must be within the 2(1+eps) guarantee of the
    host's, and both must contain the planted dense block."""
    rng = np.random.default_rng(8)
    n, m = 500, 2000
    src = rng.integers(0, n, m)
    dst = rng.integers(0, n, m)
    keep = src != dst
    src, dst = src[keep], dst[keep]
    c = np.ones(src.shape[0], np.float32)
    block = np.arange(12)
    bs_, bd_ = np.meshgrid(block, block)
    mb = bs_ < bd_
    src = np.concatenate([src, bs_[mb]])
    dst = np.concatenate([dst, bd_[mb]])
    c = np.concatenate([c, np.full(mb.sum(), 15.0, np.float32)])

    sp = Spade(metric="DW")
    sp.LoadGraph(src, dst, c.astype(np.float64), n_vertices=n)
    comm_host, g_host = sp.Detect()

    g = device_graph_from_coo(n, src, dst, c, e_capacity=src.shape[0] + 64)
    st = init_state(g, eps=0.1)
    comm_dev = np.where(np.asarray(st.community))[0]
    assert float(st.best_g) >= g_host / (2 * 1.1) - 1e-4
    assert set(block.tolist()).issubset(set(comm_host.tolist()))
    assert set(block.tolist()).issubset(set(comm_dev.tolist()))


def test_sampler_blocks_are_valid():
    rng = np.random.default_rng(0)
    n, m = 5000, 40000
    src = rng.integers(0, n, m).astype(np.int32)
    dst = rng.integers(0, n, m).astype(np.int64)
    csr = build_csr_neighbors(n, src, dst)
    seeds = rng.choice(n, 64, replace=False)
    blk = sample_fanout(csr, seeds, (5, 3), rng)
    assert blk.edge_src.max() < blk.nodes.shape[0]
    assert blk.edge_dst.max() < blk.nodes.shape[0]
    # seeds come first and map to themselves
    np.testing.assert_array_equal(blk.nodes[blk.seeds], np.asarray(seeds))
    # every sampled edge's endpoints exist in the node table
    assert blk.edge_mask.all()


@pytest.mark.slow
def test_dryrun_subprocess_one_cell(tmp_path):
    """The actual deliverable-(e) machinery: 512 fake devices, production
    mesh, lower+compile one cell in a fresh process."""
    out = str(tmp_path / "dry")
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "gat-cora",
         "--shape", "molecule", "--mesh", "multi", "--out", out],
        env={**os.environ, "PYTHONPATH": os.path.join(REPO, "src")},
        capture_output=True, text=True, timeout=480, cwd=REPO,
    )
    assert r.returncode == 0, r.stdout + r.stderr
    assert "0 failures" in r.stdout
