"""Device plane (JAX) vs host oracle: exact peel equality, bulk-peel
guarantees, and incremental suffix re-peel invariants."""

from __future__ import annotations

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.incremental import (
    benign_mask,
    full_refresh,
    init_state,
    insert_and_maintain,
)
from repro.core.peel import bulk_peel, bulk_peel_warm, exact_peel
from repro.core.reference import AdjGraph, detect, static_peel
from repro.graphstore.structs import device_graph_from_coo

jax.config.update("jax_platform_name", "cpu")


def random_coo(rng, n, m, int_weights=True):
    src = rng.integers(0, n, m)
    dst = rng.integers(0, n, m)
    keep = src != dst
    src, dst = src[keep], dst[keep]
    c = rng.integers(1, 6, src.shape[0]).astype(np.float32)
    a = rng.integers(0, 3, n).astype(np.float32)
    return src, dst, c, a


def to_oracle(n, src, dst, c, a):
    return AdjGraph.from_arrays(n, src, dst, c, a)


# ---------------------------------------------------------------------------
# exact sequential peel == host oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", range(4))
def test_exact_peel_matches_oracle(seed):
    rng = np.random.default_rng(seed)
    n, m = 24, 70
    src, dst, c, a = random_coo(rng, n, m)
    g = device_graph_from_coo(n, src, dst, c, a)
    res = jax.jit(exact_peel)(g)
    host = static_peel(to_oracle(n, src, dst, c, a))
    np.testing.assert_array_equal(np.asarray(res.order[:n]), host.order())
    np.testing.assert_allclose(np.asarray(res.delta[:n]), host.delta(), rtol=1e-6)
    _, g_host = detect(host)
    assert np.isclose(float(res.best_g), g_host, rtol=1e-6)


def test_exact_peel_with_capacity_padding():
    rng = np.random.default_rng(9)
    n, m = 15, 40
    src, dst, c, a = random_coo(rng, n, m)
    g = device_graph_from_coo(n, src, dst, c, a, n_capacity=32, e_capacity=128)
    res = jax.jit(exact_peel)(g)
    host = static_peel(to_oracle(n, src, dst, c, a))
    np.testing.assert_array_equal(np.asarray(res.order[:n]), host.order())


# ---------------------------------------------------------------------------
# bulk peel: approximation guarantee + planted-community recovery
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed,eps", [(0, 0.1), (1, 0.1), (2, 0.5), (3, 0.01)])
def test_bulk_peel_guarantee_vs_exact(seed, eps):
    rng = np.random.default_rng(seed)
    n, m = 40, 150
    src, dst, c, a = random_coo(rng, n, m)
    g = device_graph_from_coo(n, src, dst, c, a)
    bulk = bulk_peel(g, eps=eps)
    host = static_peel(to_oracle(n, src, dst, c, a))
    _, g_seq = detect(host)
    # sequential-peel best is itself >= g*/2; bulk must be >= g*/(2(1+eps))
    # and g* >= g_seq, so bulk >= g_seq / (2(1+eps)) is implied; check the
    # direct relation instead: bulk best cannot beat optimal, and must be
    # within its guarantee of the sequential result.
    assert float(bulk.best_g) >= g_seq / (2.0 * (1.0 + eps)) - 1e-5
    # community mask consistent with level bookkeeping
    comm = np.asarray(bulk.community_mask() & g.vertex_mask)
    assert comm.sum() > 0


def test_bulk_peel_finds_planted_clique():
    rng = np.random.default_rng(5)
    n = 200
    src, dst, c, a = random_coo(rng, n, 300)
    block = np.arange(10)
    bs, bd = np.meshgrid(block, block)
    mask = bs < bd
    src = np.concatenate([src, bs[mask]])
    dst = np.concatenate([dst, bd[mask]])
    c = np.concatenate([c, np.full(mask.sum(), 10.0, np.float32)])
    g = device_graph_from_coo(n, src, dst, c, a)
    res = bulk_peel(g, eps=0.1)
    comm = np.where(np.asarray(res.community_mask()))[0]
    assert set(block.tolist()).issubset(set(comm.tolist()))
    assert int(res.n_rounds) < n  # genuinely bulk: far fewer rounds than V


# ---------------------------------------------------------------------------
# incremental maintenance
# ---------------------------------------------------------------------------


def test_incremental_matches_refresh_guarantee():
    rng = np.random.default_rng(6)
    n, m = 100, 250
    src, dst, c, a = random_coo(rng, n, m)
    g = device_graph_from_coo(n, src, dst, c, a, e_capacity=m + 256)
    state = init_state(g, eps=0.1)

    # stream 8 batches of 16 edges
    for i in range(8):
        bs = rng.integers(0, n, 16).astype(np.int32)
        bd = rng.integers(0, n, 16).astype(np.int32)
        valid = bs != bd
        bc = rng.integers(1, 6, 16).astype(np.float32)
        state = insert_and_maintain(
            state, jnp.asarray(bs), jnp.asarray(bd), jnp.asarray(bc),
            jnp.asarray(valid), eps=0.1
        )

    # maintained best must be >= the from-scratch bulk best / never regress,
    # and both must satisfy the guarantee vs the exact sequential peel.
    fresh = full_refresh(state, eps=0.1)
    assert float(state.best_g) >= float(fresh.best_g) - 1e-5
    host_g = to_oracle(
        n,
        np.asarray(state.graph.src)[np.asarray(state.graph.edge_mask)],
        np.asarray(state.graph.dst)[np.asarray(state.graph.edge_mask)],
        np.asarray(state.graph.c)[np.asarray(state.graph.edge_mask)],
        np.asarray(state.graph.a)[:n],
    )
    _, g_seq = detect(static_peel(host_g))
    assert float(state.best_g) >= g_seq / 2.2 - 1e-5


def test_incremental_detects_emerging_fraud_block():
    rng = np.random.default_rng(7)
    n, m = 150, 300
    src, dst, c, a = random_coo(rng, n, m)
    g = device_graph_from_coo(n, src, dst, c, a, e_capacity=m + 512)
    state = init_state(g, eps=0.1)
    g0 = float(state.best_g)

    block = np.arange(20, 28)
    for u in block:
        for v in block:
            if u < v:
                state = insert_and_maintain(
                    state,
                    jnp.asarray([u], jnp.int32),
                    jnp.asarray([v], jnp.int32),
                    jnp.asarray([8.0], jnp.float32),
                    jnp.asarray([True]),
                    eps=0.1,
                )
    comm = np.where(np.asarray(state.community))[0]
    assert set(block.tolist()).issubset(set(comm.tolist()))
    assert float(state.best_g) > g0


def test_benign_mask_is_conservative():
    rng = np.random.default_rng(8)
    n, m = 80, 200
    src, dst, c, a = random_coo(rng, n, m)
    g = device_graph_from_coo(n, src, dst, c, a, e_capacity=m + 64)
    state = init_state(g, eps=0.1)
    # heavy edge into the current community must be urgent
    comm = np.where(np.asarray(state.community))[0]
    bm = benign_mask(
        state,
        jnp.asarray([comm[0]], jnp.int32),
        jnp.asarray([comm[-1]], jnp.int32),
        jnp.asarray([100.0], jnp.float32),
    )
    assert not bool(bm[0])


def test_empty_batch_noop():
    rng = np.random.default_rng(10)
    n, m = 30, 60
    src, dst, c, a = random_coo(rng, n, m)
    g = device_graph_from_coo(n, src, dst, c, a, e_capacity=m + 32)
    state = init_state(g, eps=0.1)
    lvl0 = np.asarray(state.level).copy()
    m_real = int(jnp.sum(g.edge_mask))
    z = jnp.zeros(4, jnp.int32)
    state2 = insert_and_maintain(
        state, z, z, z.astype(jnp.float32), jnp.zeros(4, bool), eps=0.1
    )
    assert int(state2.edge_count) == m_real
    np.testing.assert_array_equal(np.asarray(state2.level), lvl0)


# ---------------------------------------------------------------------------
# benign/urgent routing: device benign_mask == host oracle Def 4.1
# ---------------------------------------------------------------------------


def test_benign_mask_matches_host_urgency_stream():
    """Edge-by-edge over a random stream, the vectorized benign_mask agrees
    with the host oracle's Def 4.1 urgency test.

    Both tests read the same exact g(S^P) (host-maintained), so the
    comparison isolates the device plane's incrementally maintained w0 and
    the vectorized test itself; integer weights keep every sum exact."""
    import dataclasses

    from repro.core.reference import insert_edges, peeling_weights_full

    rng = np.random.default_rng(5)
    n, m = 30, 60
    src, dst, c, a = random_coo(rng, n, m)
    # plant a heavy block so g(S^P) is high and sparse-endpoint edges are
    # genuinely benign — both branches of Def 4.1 get exercised
    block = np.arange(6)
    bs_, bd_ = np.meshgrid(block, block)
    tri = bs_ < bd_
    src = np.concatenate([src, bs_[tri]])
    dst = np.concatenate([dst, bd_[tri]])
    c = np.concatenate([c, np.full(tri.sum(), 40.0, np.float32)])
    g_dev = device_graph_from_coo(n, src, dst, c, a, e_capacity=src.shape[0] + 128)
    state = init_state(g_dev, eps=0.1)
    host = to_oracle(n, src, dst, c, a)
    host_state = static_peel(host)
    _, g_best = detect(host_state)
    w0_host = peeling_weights_full(host)
    np.testing.assert_allclose(np.asarray(state.w0)[:n], w0_host, rtol=1e-6)

    checked_benign = checked_urgent = 0
    for _ in range(40):
        u, v = (int(x) for x in rng.integers(0, n, 2))
        if u == v:
            continue
        cv = float(rng.integers(1, 5))
        host_urgent = (w0_host[u] + cv >= g_best) or (w0_host[v] + cv >= g_best)
        dev = dataclasses.replace(state, best_g=jnp.float32(g_best))
        dev_benign = bool(
            benign_mask(
                dev,
                jnp.asarray([u], jnp.int32),
                jnp.asarray([v], jnp.int32),
                jnp.asarray([cv], jnp.float32),
            )[0]
        )
        assert dev_benign == (not host_urgent), (u, v, cv)
        checked_benign += dev_benign
        checked_urgent += not dev_benign
        # apply the edge on both planes, then re-check w0 parity
        insert_edges(host_state, [(u, v, cv)])
        w0_host[u] += cv
        w0_host[v] += cv
        _, g_best = detect(host_state)
        state = insert_and_maintain(
            state,
            jnp.asarray([u], jnp.int32),
            jnp.asarray([v], jnp.int32),
            jnp.asarray([cv], jnp.float32),
            jnp.asarray([True]),
            eps=0.1,
        )
        np.testing.assert_allclose(np.asarray(state.w0)[:n], w0_host, rtol=1e-6)
    assert checked_benign > 0 and checked_urgent > 0  # both branches exercised


def test_benign_mask_matches_host_urgency_with_deletions():
    """Satellite of the sliding-window work: the vectorized Def 4.1 test
    must keep agreeing with the host oracle when edges are *deleted* —
    the decremented device w0 and the (possibly regressed) best density
    both enter the urgency test.  Unique edge pairs keep the host's
    combined-adjacency deletion 1:1 with device slots; integer weights
    keep every sum exact."""
    import dataclasses

    from repro.core.incremental import delete_and_maintain
    from repro.core.reference import delete_edge, peeling_weights_full

    rng = np.random.default_rng(12)
    n = 24
    pairs = [(u, v) for u in range(n) for v in range(u + 1, n)]
    rng.shuffle(pairs)
    pairs = pairs[:80]
    src = np.array([p[0] for p in pairs])
    dst = np.array([p[1] for p in pairs])
    c = rng.integers(1, 6, len(pairs)).astype(np.float32)
    # heavy block so benign edges genuinely exist
    for i in range(10):
        c[i] = 40.0
    a = rng.integers(0, 3, n).astype(np.float32)
    g_dev = device_graph_from_coo(n, src, dst, c, a, e_capacity=len(pairs) + 32)
    state = init_state(g_dev, eps=0.1)
    host = to_oracle(n, src, dst, c, a)
    host_state = static_peel(host)
    _, g_best = detect(host_state)
    w0_host = peeling_weights_full(host)

    live = list(range(len(pairs)))
    checked_benign = checked_urgent = 0
    slot_ids = jnp.arange(g_dev.e_capacity, dtype=jnp.int32)
    for step in range(12):
        # delete one live edge on both planes
        k = live[int(rng.integers(0, len(live)))]
        em = np.asarray(state.graph.edge_mask)
        slot = [
            i for i in range(em.sum())
            if (int(np.asarray(state.graph.src)[i]),
                int(np.asarray(state.graph.dst)[i])) == pairs[k]
        ][0]
        state = delete_and_maintain(state, slot_ids == slot, eps=0.1)
        delete_edge(host_state, *pairs[k])
        w0_host[pairs[k][0]] -= c[k]
        w0_host[pairs[k][1]] -= c[k]
        live.remove(k)
        _, g_best = detect(host_state)
        np.testing.assert_allclose(np.asarray(state.w0)[:n], w0_host, rtol=1e-6)

        # device benign test (with the host's exact g to isolate w0) must
        # equal host urgency for random candidate edges
        for _ in range(6):
            u, v = (int(x) for x in rng.integers(0, n, 2))
            if u == v:
                continue
            cv = float(rng.integers(1, 5))
            host_urgent = (
                w0_host[u] + cv >= g_best or w0_host[v] + cv >= g_best
            )
            dev = dataclasses.replace(state, best_g=jnp.float32(g_best))
            dev_benign = bool(
                benign_mask(
                    dev,
                    jnp.asarray([u], jnp.int32),
                    jnp.asarray([v], jnp.int32),
                    jnp.asarray([cv], jnp.float32),
                )[0]
            )
            assert dev_benign == (not host_urgent), (step, u, v, cv)
            checked_benign += dev_benign
            checked_urgent += not dev_benign
    assert checked_benign > 0 and checked_urgent > 0


def test_edge_grouping_buffered_edges_then_deleted():
    """Grouping + deletion interaction: benign edges sit in the buffer,
    then the very same edges are deleted.  DeleteEdge must flush first
    (the buffered edge has to exist in the graph to be removable) and the
    final state must equal a scratch peel without the deleted edge."""
    from repro.core.spade import Spade

    sp = Spade(metric="DW", edge_grouping=True)
    # heavy triangle 0-1-2 keeps g(S^P) high; 3 and 4 hang off it lightly,
    # so an edge between them is benign under Def 4.1
    sp.LoadGraph([0, 1, 2, 0, 0], [1, 2, 0, 3, 4],
                 [100.0, 100.0, 100.0, 1.0, 1.0], n_vertices=5)
    r1 = sp.InsertEdge(3, 4, 1.0)  # benign: buffers
    assert not r1.triggered and sp.buffered_edges == 1
    res = sp.DeleteEdge(3, 4)  # deletes the edge that was still buffered
    assert res.triggered and sp.buffered_edges == 0
    assert 4 not in sp.graph.adj[3]
    expect = static_peel(sp.graph.copy())
    np.testing.assert_array_equal(sp.state.order(), expect.order())
    np.testing.assert_allclose(sp.state.delta(), expect.delta())

    # buffered benign edge NOT deleted must survive a deletion elsewhere
    r2 = sp.InsertEdge(4, 3, 1.0)
    assert not r2.triggered and sp.buffered_edges == 1
    sp.DeleteEdge(0, 3)
    assert sp.buffered_edges == 0  # flush-first semantics
    assert 3 in sp.graph.adj[4]  # the buffered edge was materialized
    expect = static_peel(sp.graph.copy())
    np.testing.assert_array_equal(sp.state.order(), expect.order())
    # w0 stayed exact through buffer + flush + delete accounting
    from repro.core.reference import peeling_weights_full

    np.testing.assert_allclose(sp._w0[: sp.graph.n],
                               peeling_weights_full(sp.graph))


def test_spade_insert_delete_window_equals_scratch():
    """Host-plane C.3 window: inserts + expiries through the public API
    track a scratch peel of the surviving graph exactly."""
    from repro.core.spade import Spade

    rng = np.random.default_rng(21)
    n = 20
    base = []
    seen = set()
    while len(base) < 40:
        u, v = (int(x) for x in rng.integers(0, n, 2))
        if u == v or (u, v) in seen or (v, u) in seen:
            continue
        seen.add((u, v))
        base.append((u, v, float(rng.integers(1, 6))))
    sp = Spade(metric="DW")
    sp.LoadGraph([e[0] for e in base], [e[1] for e in base],
                 [e[2] for e in base], n_vertices=n)
    window = list(base)
    for _ in range(15):
        # slide: insert a fresh unique edge, expire the oldest
        while True:
            u, v = (int(x) for x in rng.integers(0, n, 2))
            if u != v and (u, v) not in seen and (v, u) not in seen:
                break
        seen.add((u, v))
        cv = float(rng.integers(1, 6))
        sp.InsertEdge(u, v, cv)
        window.append((u, v, cv))
        old = window.pop(0)
        sp.DeleteEdge(old[0], old[1])
        seen.discard((old[0], old[1]))
        expect = static_peel(sp.graph.copy())
        np.testing.assert_array_equal(sp.state.order(), expect.order())
        np.testing.assert_allclose(sp.state.delta(), expect.delta())


def test_append_compacts_interior_invalid_batch_entries():
    """Regression: the k-th *valid* edge of a batch must land in slot
    offset+k, or a later batch (offset advanced by sum(valid)) silently
    overwrites earlier edges when invalid entries sit between valid ones."""
    g = device_graph_from_coo(
        6, np.array([0]), np.array([1]), np.ones(1, np.float32), e_capacity=8
    )
    state = init_state(g, eps=0.1)
    s1 = insert_and_maintain(
        state,
        jnp.asarray([0, 3], jnp.int32), jnp.asarray([0, 4], jnp.int32),
        jnp.ones(2, jnp.float32), jnp.asarray([False, True]), eps=0.1,
    )
    s2 = insert_and_maintain(
        s1,
        jnp.asarray([4, 2], jnp.int32), jnp.asarray([5, 5], jnp.int32),
        jnp.ones(2, jnp.float32), jnp.asarray([True, True]), eps=0.1,
    )
    em = np.asarray(s2.graph.edge_mask)
    edges = set(zip(np.asarray(s2.graph.src)[em].tolist(),
                    np.asarray(s2.graph.dst)[em].tolist()))
    assert edges == {(0, 1), (3, 4), (4, 5), (2, 5)}
    assert int(s2.edge_count) == 4
