"""Device plane (JAX) vs host oracle: exact peel equality, bulk-peel
guarantees, and incremental suffix re-peel invariants."""

from __future__ import annotations

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.incremental import (
    benign_mask,
    full_refresh,
    init_state,
    insert_and_maintain,
)
from repro.core.peel import bulk_peel, bulk_peel_warm, exact_peel
from repro.core.reference import AdjGraph, detect, static_peel
from repro.graphstore.structs import device_graph_from_coo

jax.config.update("jax_platform_name", "cpu")


def random_coo(rng, n, m, int_weights=True):
    src = rng.integers(0, n, m)
    dst = rng.integers(0, n, m)
    keep = src != dst
    src, dst = src[keep], dst[keep]
    c = rng.integers(1, 6, src.shape[0]).astype(np.float32)
    a = rng.integers(0, 3, n).astype(np.float32)
    return src, dst, c, a


def to_oracle(n, src, dst, c, a):
    return AdjGraph.from_arrays(n, src, dst, c, a)


# ---------------------------------------------------------------------------
# exact sequential peel == host oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", range(4))
def test_exact_peel_matches_oracle(seed):
    rng = np.random.default_rng(seed)
    n, m = 24, 70
    src, dst, c, a = random_coo(rng, n, m)
    g = device_graph_from_coo(n, src, dst, c, a)
    res = jax.jit(exact_peel)(g)
    host = static_peel(to_oracle(n, src, dst, c, a))
    np.testing.assert_array_equal(np.asarray(res.order[:n]), host.order())
    np.testing.assert_allclose(np.asarray(res.delta[:n]), host.delta(), rtol=1e-6)
    _, g_host = detect(host)
    assert np.isclose(float(res.best_g), g_host, rtol=1e-6)


def test_exact_peel_with_capacity_padding():
    rng = np.random.default_rng(9)
    n, m = 15, 40
    src, dst, c, a = random_coo(rng, n, m)
    g = device_graph_from_coo(n, src, dst, c, a, n_capacity=32, e_capacity=128)
    res = jax.jit(exact_peel)(g)
    host = static_peel(to_oracle(n, src, dst, c, a))
    np.testing.assert_array_equal(np.asarray(res.order[:n]), host.order())


# ---------------------------------------------------------------------------
# bulk peel: approximation guarantee + planted-community recovery
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed,eps", [(0, 0.1), (1, 0.1), (2, 0.5), (3, 0.01)])
def test_bulk_peel_guarantee_vs_exact(seed, eps):
    rng = np.random.default_rng(seed)
    n, m = 40, 150
    src, dst, c, a = random_coo(rng, n, m)
    g = device_graph_from_coo(n, src, dst, c, a)
    bulk = bulk_peel(g, eps=eps)
    host = static_peel(to_oracle(n, src, dst, c, a))
    _, g_seq = detect(host)
    # sequential-peel best is itself >= g*/2; bulk must be >= g*/(2(1+eps))
    # and g* >= g_seq, so bulk >= g_seq / (2(1+eps)) is implied; check the
    # direct relation instead: bulk best cannot beat optimal, and must be
    # within its guarantee of the sequential result.
    assert float(bulk.best_g) >= g_seq / (2.0 * (1.0 + eps)) - 1e-5
    # community mask consistent with level bookkeeping
    comm = np.asarray(bulk.community_mask() & g.vertex_mask)
    assert comm.sum() > 0


def test_bulk_peel_finds_planted_clique():
    rng = np.random.default_rng(5)
    n = 200
    src, dst, c, a = random_coo(rng, n, 300)
    block = np.arange(10)
    bs, bd = np.meshgrid(block, block)
    mask = bs < bd
    src = np.concatenate([src, bs[mask]])
    dst = np.concatenate([dst, bd[mask]])
    c = np.concatenate([c, np.full(mask.sum(), 10.0, np.float32)])
    g = device_graph_from_coo(n, src, dst, c, a)
    res = bulk_peel(g, eps=0.1)
    comm = np.where(np.asarray(res.community_mask()))[0]
    assert set(block.tolist()).issubset(set(comm.tolist()))
    assert int(res.n_rounds) < n  # genuinely bulk: far fewer rounds than V


# ---------------------------------------------------------------------------
# incremental maintenance
# ---------------------------------------------------------------------------


def test_incremental_matches_refresh_guarantee():
    rng = np.random.default_rng(6)
    n, m = 100, 250
    src, dst, c, a = random_coo(rng, n, m)
    g = device_graph_from_coo(n, src, dst, c, a, e_capacity=m + 256)
    state = init_state(g, eps=0.1)

    # stream 8 batches of 16 edges
    for i in range(8):
        bs = rng.integers(0, n, 16).astype(np.int32)
        bd = rng.integers(0, n, 16).astype(np.int32)
        valid = bs != bd
        bc = rng.integers(1, 6, 16).astype(np.float32)
        state = insert_and_maintain(
            state, jnp.asarray(bs), jnp.asarray(bd), jnp.asarray(bc),
            jnp.asarray(valid), eps=0.1
        )

    # maintained best must be >= the from-scratch bulk best / never regress,
    # and both must satisfy the guarantee vs the exact sequential peel.
    fresh = full_refresh(state, eps=0.1)
    assert float(state.best_g) >= float(fresh.best_g) - 1e-5
    host_g = to_oracle(
        n,
        np.asarray(state.graph.src)[np.asarray(state.graph.edge_mask)],
        np.asarray(state.graph.dst)[np.asarray(state.graph.edge_mask)],
        np.asarray(state.graph.c)[np.asarray(state.graph.edge_mask)],
        np.asarray(state.graph.a)[:n],
    )
    _, g_seq = detect(static_peel(host_g))
    assert float(state.best_g) >= g_seq / 2.2 - 1e-5


def test_incremental_detects_emerging_fraud_block():
    rng = np.random.default_rng(7)
    n, m = 150, 300
    src, dst, c, a = random_coo(rng, n, m)
    g = device_graph_from_coo(n, src, dst, c, a, e_capacity=m + 512)
    state = init_state(g, eps=0.1)
    g0 = float(state.best_g)

    block = np.arange(20, 28)
    for u in block:
        for v in block:
            if u < v:
                state = insert_and_maintain(
                    state,
                    jnp.asarray([u], jnp.int32),
                    jnp.asarray([v], jnp.int32),
                    jnp.asarray([8.0], jnp.float32),
                    jnp.asarray([True]),
                    eps=0.1,
                )
    comm = np.where(np.asarray(state.community))[0]
    assert set(block.tolist()).issubset(set(comm.tolist()))
    assert float(state.best_g) > g0


def test_benign_mask_is_conservative():
    rng = np.random.default_rng(8)
    n, m = 80, 200
    src, dst, c, a = random_coo(rng, n, m)
    g = device_graph_from_coo(n, src, dst, c, a, e_capacity=m + 64)
    state = init_state(g, eps=0.1)
    # heavy edge into the current community must be urgent
    comm = np.where(np.asarray(state.community))[0]
    bm = benign_mask(
        state,
        jnp.asarray([comm[0]], jnp.int32),
        jnp.asarray([comm[-1]], jnp.int32),
        jnp.asarray([100.0], jnp.float32),
    )
    assert not bool(bm[0])


def test_empty_batch_noop():
    rng = np.random.default_rng(10)
    n, m = 30, 60
    src, dst, c, a = random_coo(rng, n, m)
    g = device_graph_from_coo(n, src, dst, c, a, e_capacity=m + 32)
    state = init_state(g, eps=0.1)
    lvl0 = np.asarray(state.level).copy()
    m_real = int(jnp.sum(g.edge_mask))
    z = jnp.zeros(4, jnp.int32)
    state2 = insert_and_maintain(
        state, z, z, z.astype(jnp.float32), jnp.zeros(4, bool), eps=0.1
    )
    assert int(state2.edge_count) == m_real
    np.testing.assert_array_equal(np.asarray(state2.level), lvl0)


# ---------------------------------------------------------------------------
# benign/urgent routing: device benign_mask == host oracle Def 4.1
# ---------------------------------------------------------------------------


def test_benign_mask_matches_host_urgency_stream():
    """Edge-by-edge over a random stream, the vectorized benign_mask agrees
    with the host oracle's Def 4.1 urgency test.

    Both tests read the same exact g(S^P) (host-maintained), so the
    comparison isolates the device plane's incrementally maintained w0 and
    the vectorized test itself; integer weights keep every sum exact."""
    import dataclasses

    from repro.core.reference import insert_edges, peeling_weights_full

    rng = np.random.default_rng(5)
    n, m = 30, 60
    src, dst, c, a = random_coo(rng, n, m)
    # plant a heavy block so g(S^P) is high and sparse-endpoint edges are
    # genuinely benign — both branches of Def 4.1 get exercised
    block = np.arange(6)
    bs_, bd_ = np.meshgrid(block, block)
    tri = bs_ < bd_
    src = np.concatenate([src, bs_[tri]])
    dst = np.concatenate([dst, bd_[tri]])
    c = np.concatenate([c, np.full(tri.sum(), 40.0, np.float32)])
    g_dev = device_graph_from_coo(n, src, dst, c, a, e_capacity=src.shape[0] + 128)
    state = init_state(g_dev, eps=0.1)
    host = to_oracle(n, src, dst, c, a)
    host_state = static_peel(host)
    _, g_best = detect(host_state)
    w0_host = peeling_weights_full(host)
    np.testing.assert_allclose(np.asarray(state.w0)[:n], w0_host, rtol=1e-6)

    checked_benign = checked_urgent = 0
    for _ in range(40):
        u, v = (int(x) for x in rng.integers(0, n, 2))
        if u == v:
            continue
        cv = float(rng.integers(1, 5))
        host_urgent = (w0_host[u] + cv >= g_best) or (w0_host[v] + cv >= g_best)
        dev = dataclasses.replace(state, best_g=jnp.float32(g_best))
        dev_benign = bool(
            benign_mask(
                dev,
                jnp.asarray([u], jnp.int32),
                jnp.asarray([v], jnp.int32),
                jnp.asarray([cv], jnp.float32),
            )[0]
        )
        assert dev_benign == (not host_urgent), (u, v, cv)
        checked_benign += dev_benign
        checked_urgent += not dev_benign
        # apply the edge on both planes, then re-check w0 parity
        insert_edges(host_state, [(u, v, cv)])
        w0_host[u] += cv
        w0_host[v] += cv
        _, g_best = detect(host_state)
        state = insert_and_maintain(
            state,
            jnp.asarray([u], jnp.int32),
            jnp.asarray([v], jnp.int32),
            jnp.asarray([cv], jnp.float32),
            jnp.asarray([True]),
            eps=0.1,
        )
        np.testing.assert_allclose(np.asarray(state.w0)[:n], w0_host, rtol=1e-6)
    assert checked_benign > 0 and checked_urgent > 0  # both branches exercised


def test_append_compacts_interior_invalid_batch_entries():
    """Regression: the k-th *valid* edge of a batch must land in slot
    offset+k, or a later batch (offset advanced by sum(valid)) silently
    overwrites earlier edges when invalid entries sit between valid ones."""
    g = device_graph_from_coo(
        6, np.array([0]), np.array([1]), np.ones(1, np.float32), e_capacity=8
    )
    state = init_state(g, eps=0.1)
    s1 = insert_and_maintain(
        state,
        jnp.asarray([0, 3], jnp.int32), jnp.asarray([0, 4], jnp.int32),
        jnp.ones(2, jnp.float32), jnp.asarray([False, True]), eps=0.1,
    )
    s2 = insert_and_maintain(
        s1,
        jnp.asarray([4, 2], jnp.int32), jnp.asarray([5, 5], jnp.int32),
        jnp.ones(2, jnp.float32), jnp.asarray([True, True]), eps=0.1,
    )
    em = np.asarray(s2.graph.edge_mask)
    edges = set(zip(np.asarray(s2.graph.src)[em].tolist(),
                    np.asarray(s2.graph.dst)[em].tolist()))
    assert edges == {(0, 1), (3, 4), (4, 5), (2, 5)}
    assert int(s2.edge_count) == 4
