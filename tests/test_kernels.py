"""Pallas kernel validation: interpret-mode execution vs pure-jnp oracles,
swept over shapes and dtypes (per-kernel allclose)."""

from __future__ import annotations

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_fwd
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.gather_segsum.ops import build_tiles, gather_segsum
from repro.kernels.gather_segsum.ref import spmm_ref
from repro.kernels.peel_round.kernel import peel_round_update
from repro.kernels.peel_round.ref import peel_round_ref

# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

ATTN_SWEEP = [
    # (B, Hq, Hkv, Sq, Skv, D, causal, window, dtype)
    (1, 2, 2, 128, 128, 64, True, None, jnp.float32),
    (2, 4, 2, 256, 256, 64, True, None, jnp.float32),
    (1, 8, 2, 128, 128, 128, True, None, jnp.float32),
    (1, 2, 1, 256, 256, 64, False, None, jnp.float32),
    (1, 4, 4, 384, 384, 64, True, 128, jnp.float32),  # sliding window
    (1, 2, 2, 200, 200, 64, True, None, jnp.float32),  # ragged (padding)
    (1, 2, 2, 128, 128, 64, True, None, jnp.bfloat16),
]


@pytest.mark.parametrize(
    "B,Hq,Hkv,Sq,Skv,D,causal,window,dtype", ATTN_SWEEP,
    ids=[f"attn{i}" for i in range(len(ATTN_SWEEP))],
)
def test_flash_attention_interpret_vs_ref(B, Hq, Hkv, Sq, Skv, D, causal, window, dtype):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(k1, (B, Hq, Sq, D), dtype)
    k = jax.random.normal(k2, (B, Hkv, Skv, D), dtype)
    v = jax.random.normal(k3, (B, Hkv, Skv, D), dtype)
    got = flash_attention_fwd(q, k, v, causal=causal, window=window,
                              block_q=128, block_k=128, interpret=True)
    want = attention_ref(q, k, v, causal=causal, window=window)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), atol=tol, rtol=tol
    )


def test_flash_attention_matches_model_attention():
    """The kernel and the model's jnp flash implementation agree."""
    from repro.models.attention import flash_attention as model_flash

    B, Hq, Hkv, S, D = 1, 4, 2, 256, 64
    G = Hq // Hkv
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(k1, (B, S, Hkv, G, D), jnp.float32)
    k = jax.random.normal(k2, (B, S, Hkv, D), jnp.float32)
    v = jax.random.normal(k3, (B, S, Hkv, D), jnp.float32)
    got_model = model_flash(q, k, v, causal=True, q_block=128, kv_block=128)
    qk = q.transpose(0, 2, 3, 1, 4).reshape(B, Hq, S, D)
    got_kernel = flash_attention_fwd(qk, k.transpose(0, 2, 1, 3),
                                     v.transpose(0, 2, 1, 3),
                                     causal=True, block_q=128, block_k=128,
                                     interpret=True)
    want = got_kernel.reshape(B, Hkv, G, S, D).transpose(0, 3, 1, 2, 4)
    np.testing.assert_allclose(np.asarray(got_model), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
# gather_segsum (block SpMM)
# ---------------------------------------------------------------------------

SPMM_SWEEP = [
    # (n_dst, n_src, n_edges, F, seed)
    (256, 256, 1000, 64, 0),
    (300, 200, 700, 16, 1),  # non-multiple of block
    (128, 512, 2000, 128, 2),
    (512, 512, 100, 200, 3),  # sparse, F > f_tile
]


@pytest.mark.parametrize("n_dst,n_src,m,F,seed", SPMM_SWEEP,
                         ids=[f"spmm{i}" for i in range(len(SPMM_SWEEP))])
def test_block_spmm_interpret_vs_ref(n_dst, n_src, m, F, seed):
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n_src, m).astype(np.int32)
    dst = rng.integers(0, n_dst, m).astype(np.int32)
    val = rng.normal(size=m).astype(np.float32)
    x = jnp.asarray(rng.normal(size=(n_src, F)).astype(np.float32))
    bt = build_tiles(src, dst, val, n_dst, n_src)
    got = gather_segsum(bt, x, n_dst, force="interpret")
    want = spmm_ref(jnp.asarray(src), jnp.asarray(dst), jnp.asarray(val), x, n_dst)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4, rtol=1e-4)


def test_block_spmm_occupancy_reported():
    rng = np.random.default_rng(0)
    src = rng.integers(0, 1024, 5000).astype(np.int32)
    dst = rng.integers(0, 1024, 5000).astype(np.int32)
    bt = build_tiles(src, dst, None, 1024, 1024)
    assert 0 < bt.occupancy <= 1


# ---------------------------------------------------------------------------
# peel_round
# ---------------------------------------------------------------------------

PEEL_SWEEP = [(1000, 0), (8192, 1), (10000, 2), (100, 3)]


@pytest.mark.parametrize("V,seed", PEEL_SWEEP,
                         ids=[f"peel{v}" for v, _ in PEEL_SWEEP])
def test_peel_round_interpret_vs_ref(V, seed):
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.uniform(0, 10, V).astype(np.float32))
    a = jnp.asarray(rng.uniform(0, 2, V).astype(np.float32))
    active = jnp.asarray(rng.random(V) > 0.3)
    level = jnp.asarray(rng.integers(-1, 5, V).astype(np.int32))
    dw = jnp.asarray(rng.uniform(0, 1, V).astype(np.float32))
    thresh = jnp.float32(5.0)
    round_ = jnp.int32(7)
    w2, active2, level2, peeled, partials = peel_round_update(
        w, a, active, level, dw, thresh, round_, block=1024, interpret=True
    )
    rw2, ra2, rl2, rp, rpart = peel_round_ref(w, a, active, level, dw, thresh, round_)
    np.testing.assert_allclose(np.asarray(w2), np.asarray(rw2), rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(active2), np.asarray(ra2))
    np.testing.assert_array_equal(np.asarray(level2), np.asarray(rl2))
    np.testing.assert_array_equal(np.asarray(peeled), np.asarray(rp))
    np.testing.assert_allclose(np.asarray(partials.sum(0)), np.asarray(rpart),
                               rtol=1e-5)


def test_peel_round_consistent_with_bulk_peel_semantics():
    """One fused-kernel round == one _bulk_round step (weights/masks)."""
    from repro.core.peel import _BulkState, _bulk_round
    from repro.graphstore.structs import device_graph_from_coo

    rng = np.random.default_rng(4)
    n, m = 200, 600
    src = rng.integers(0, n, m)
    dst = rng.integers(0, n, m)
    keep = src != dst
    src, dst = src[keep], dst[keep]
    c = rng.integers(1, 5, src.shape[0]).astype(np.float32)
    g = device_graph_from_coo(n, src, dst, c)
    w0 = g.peel_weights()
    f0 = g.f_total()
    st = _BulkState(w=w0, active=g.vertex_mask, edge_alive=g.edge_mask, f=f0,
                    n_act=jnp.sum(g.vertex_mask),
                    level=jnp.full(n, -1, jnp.int32), best_g=jnp.float32(-1e30),
                    best_level=jnp.int32(0), round_=jnp.int32(0))
    nxt = _bulk_round(g, 0.1, st)

    g_cur = f0 / jnp.maximum(st.n_act, 1)
    thresh = 2.0 * 1.1 * g_cur
    peeled_ref = np.asarray(st.active & (st.w <= thresh))
    cm = np.where(np.asarray(g.edge_mask), np.asarray(g.c), 0.0)
    e_ps, e_pd = peeled_ref[np.asarray(g.src)], peeled_ref[np.asarray(g.dst)]
    dw = np.zeros(n, np.float32)
    np.add.at(dw, np.asarray(g.dst), np.where(e_ps & ~e_pd, cm, 0.0))
    np.add.at(dw, np.asarray(g.src), np.where(e_pd & ~e_ps, cm, 0.0))
    w2, active2, level2, peeled, partials = peel_round_update(
        st.w, g.a, st.active, st.level, jnp.asarray(dw), thresh, st.round_,
        block=256, interpret=True,
    )
    np.testing.assert_array_equal(np.asarray(peeled), peeled_ref)
    np.testing.assert_allclose(np.asarray(w2), np.asarray(nxt.w), rtol=1e-5)
    np.testing.assert_array_equal(np.asarray(active2), np.asarray(nxt.active))


def test_bulk_peel_kernel_wired_round_parity():
    """Satellite check for the kernel wiring: ``use_kernel=True`` routes
    every round's elementwise update through ``peel_round`` (Pallas on
    TPU, pure-jnp reference elsewhere) and must reproduce the plain-jnp
    round bit-for-bit on integer weights — cold peel, warm suffix re-peel,
    and a max_rounds cutoff alike."""
    from repro.core.peel import bulk_peel, bulk_peel_warm
    from repro.graphstore.structs import device_graph_from_coo

    rng = np.random.default_rng(11)
    n, m = 150, 500
    src = rng.integers(0, n, m)
    dst = rng.integers(0, n, m)
    keep = src != dst
    src, dst = src[keep], dst[keep]
    c = rng.integers(1, 6, src.shape[0]).astype(np.float32)
    a = rng.integers(0, 3, n).astype(np.float32)
    g = device_graph_from_coo(n, src, dst, c, a)

    for kwargs in ({}, {"max_rounds": 3}):
        ref = bulk_peel(g, eps=0.1, **kwargs)
        got = bulk_peel(g, eps=0.1, use_kernel=True, **kwargs)
        np.testing.assert_array_equal(np.asarray(got.level), np.asarray(ref.level))
        assert float(got.best_g) == float(ref.best_g)
        assert int(got.best_level) == int(ref.best_level)
        assert int(got.n_rounds) == int(ref.n_rounds)

    keep_mask = jnp.asarray(np.asarray(ref.level) >= 2)
    wref = bulk_peel_warm(g, keep_mask, prior_best_g=ref.best_g, eps=0.1)
    wgot = bulk_peel_warm(g, keep_mask, prior_best_g=ref.best_g, eps=0.1,
                          use_kernel=True)
    np.testing.assert_array_equal(np.asarray(wgot.level), np.asarray(wref.level))
    assert float(wgot.best_g) == float(wref.best_g)
