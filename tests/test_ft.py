"""Fault-tolerance layer: checkpoint/restore (+async, atomic, keep-k),
restart-resume, elastic re-mesh/reshard, straggler watchdog, gradient
compression."""

from __future__ import annotations

import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.dist.compression import compress_grads, ef_compress_tree
from repro.ft.checkpoint import CheckpointManager, latest_step, load_pytree, save_pytree
from repro.ft.elastic import StepWatchdog, best_mesh_for, replan
from repro.train.optimizer import AdamConfig, init_train_state
from repro.train.train_step import make_train_step


def tiny_state():
    params = {"w": jnp.arange(12.0).reshape(3, 4), "b": jnp.ones(4)}
    return init_train_state(params)


def test_save_load_roundtrip(tmp_path):
    st = tiny_state()
    d = str(tmp_path / "ckpt")
    save_pytree(st, d, step=7)
    assert latest_step(d) == 7
    st2 = load_pytree(st, d)
    np.testing.assert_array_equal(np.asarray(st2.params["w"]), np.asarray(st.params["w"]))
    assert int(st2.step) == 0


def test_keep_k_retention_and_async(tmp_path):
    d = str(tmp_path / "ckpt")
    mgr = CheckpointManager(d, keep=2, every_steps=1)
    st = tiny_state()
    for i in range(1, 6):
        mgr.maybe_save(st, i)
    mgr.close()
    steps = sorted(
        int(x.split("_")[1]) for x in os.listdir(d) if x.startswith("step_")
    )
    assert steps == [4, 5]
    mgr.check()


def test_atomic_commit_no_tmp_left(tmp_path):
    d = str(tmp_path / "ckpt")
    save_pytree(tiny_state(), d, step=1)
    assert not any(x.endswith(".tmp") for x in os.listdir(d))


def test_resume_training_equivalence(tmp_path):
    """Train 4 steps straight == train 2, checkpoint, restore, train 2."""
    def loss(params, batch):
        pred = batch["x"] @ params["w"] + params["b"]
        return jnp.mean((pred - batch["y"]) ** 2), {}

    step = make_train_step(loss, AdamConfig(lr=1e-2, weight_decay=0.0))
    rng = np.random.default_rng(0)
    batch = {
        "x": jnp.asarray(rng.normal(size=(8, 3)).astype(np.float32)),
        "y": jnp.asarray(rng.normal(size=(8, 4)).astype(np.float32)),
    }
    sA = tiny_state()
    for _ in range(4):
        sA, _ = step(sA, batch)

    sB = tiny_state()
    for _ in range(2):
        sB, _ = step(sB, batch)
    d = str(tmp_path / "ck")
    save_pytree(sB, d, step=2)
    sB2 = load_pytree(tiny_state(), d)
    for _ in range(2):
        sB2, _ = step(sB2, batch)
    np.testing.assert_allclose(
        np.asarray(sA.params["w"]), np.asarray(sB2.params["w"]), rtol=1e-6
    )


def test_elastic_reshard_restore(tmp_path):
    """Checkpoint saved under one mesh restores onto a smaller mesh."""
    n = len(jax.devices())
    if n < 1:
        pytest.skip("no devices")
    st = tiny_state()
    d = str(tmp_path / "ck")
    save_pytree(st, d, step=1)
    mesh = best_mesh_for(1, 1)
    sh = jax.tree.map(lambda _: NamedSharding(mesh, P()), st)
    st2 = load_pytree(st, d, shardings=sh)
    np.testing.assert_array_equal(np.asarray(st2.params["b"]), np.asarray(st.params["b"]))


def test_replan_preserves_global_batch():
    plan = replan(n_devices=1, model_axis=1, global_batch=64)
    assert plan.global_batch == 64
    assert plan.per_replica_batch * plan.mesh.devices.shape[0] == 64
    with pytest.raises(ValueError):
        best_mesh_for(1, model_axis=2)


def test_watchdog_flags_stragglers():
    dog = StepWatchdog(factor=3.0, min_history=3)
    for i in range(5):
        assert not dog.observe(i, 1.0)
    assert dog.observe(5, 10.0)
    assert dog.flagged == [5]
    assert not dog.observe(6, 1.1)


def test_compression_error_feedback_converges():
    """EF compression: quantization error is re-injected, so the running sum
    of dequantized grads tracks the true sum."""
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.normal(size=(256,)).astype(np.float32)) * 0.01
    err = jnp.zeros_like(g)
    total_true, total_deq = np.zeros(256), np.zeros(256)
    for _ in range(50):
        deq, err = compress_grads(g, err)
        total_true += np.asarray(g)
        total_deq += np.asarray(deq)
    # relative drift of the accumulated signal stays bounded by one quantum
    scale = np.abs(np.asarray(g)).max() / 127.0
    assert np.abs(total_true - total_deq).max() <= scale + 1e-6


def test_compressed_training_still_learns():
    def loss(params, batch):
        pred = batch["x"] @ params["w"] + params["b"]
        return jnp.mean((pred - batch["y"]) ** 2), {}

    step = make_train_step(
        loss,
        AdamConfig(lr=1e-2, weight_decay=0.0, warmup_steps=1, total_steps=10_000),
        compress=True,
    )
    rng = np.random.default_rng(1)
    params = {"w": jnp.asarray(rng.normal(size=(3, 4)).astype(np.float32)),
              "b": jnp.zeros(4)}
    st = init_train_state(params, with_error_feedback=True)
    batch = {
        "x": jnp.asarray(rng.normal(size=(32, 3)).astype(np.float32)),
        "y": jnp.asarray(rng.normal(size=(32, 4)).astype(np.float32)),
    }
    l0 = None
    for i in range(30):
        st, m = step(st, batch)
        if i == 0:
            l0 = float(m["loss"])
    assert float(m["loss"]) < l0 * 0.7
