"""Device-plane streaming service + device metric parity tests."""

from __future__ import annotations

import numpy as np

import jax.numpy as jnp

from repro.core.device_metrics import dg_weights, dw_weights, fd_batch_weights
from repro.core.metrics import make_fd
from repro.core.reference import AdjGraph
from repro.graphstore.generators import make_transaction_stream
from repro.serve.device_service import run_device_service


def test_fd_batch_weights_match_host_metric():
    """Device FD weighting == host FD esusp at arrival time, including
    intra-batch degree evolution."""
    fd = make_fd()
    g = AdjGraph(6)
    g.add_edge(0, 2, 1.0)
    g.add_edge(1, 2, 1.0)
    in_deg = jnp.zeros(6, jnp.int32).at[jnp.asarray([2, 2])].add(1)

    batch = [(3, 2, 1.0), (4, 2, 1.0), (0, 5, 1.0)]  # two more to 2, one to 5
    host_w = []
    for u, v, raw in batch:
        host_w.append(fd.edge_susp(u, v, raw, g))
        g.add_edge(u, v, raw)
    dst = jnp.asarray([b[1] for b in batch], jnp.int32)
    valid = jnp.ones(3, bool)
    dev_w, new_deg = fd_batch_weights(in_deg, dst, valid)
    np.testing.assert_allclose(np.asarray(dev_w), np.asarray(host_w), rtol=1e-6)
    assert int(new_deg[2]) == 4 and int(new_deg[5]) == 1


def test_dg_dw_weights():
    amt = jnp.asarray([2.0, 5.0, 0.0])
    np.testing.assert_array_equal(np.asarray(dg_weights(amt)), [1, 1, 1])
    assert float(dw_weights(amt)[2]) > 0  # clamped positive


def test_device_service_detects_fraud():
    stream = make_transaction_stream(n=3000, m=15000, seed=9)
    rep = run_device_service(stream, metric="DW", batch_edges=512,
                             refresh_every=4)
    assert rep.fraud_recall >= 0.99
    assert rep.final_g > 0
    assert 0 <= rep.benign_fraction <= 1
    assert rep.n_ticks == -(-stream.inc_src.shape[0] // 512)
    assert rep.n_refreshes >= 1


def test_device_service_fd_metric():
    stream = make_transaction_stream(n=2000, m=10000, seed=10)
    rep = run_device_service(stream, metric="FD", batch_edges=512)
    assert rep.n_edges == stream.inc_src.shape[0]
    assert np.isfinite(rep.final_g)


def test_device_service_sliding_window():
    """Windowed mode: resident edges bounded by base + N ticks, expiry
    accounting closes (expired + live-beyond-base == streamed), and the
    standing ring is still detected (its base-graph edges never expire)."""
    stream = make_transaction_stream(n=2000, m=10000, seed=12)
    rep = run_device_service(stream, metric="DW", batch_edges=256,
                             window_ticks=2, refresh_every=3)
    m_base = stream.base_src.shape[0]
    assert rep.window_ticks == 2
    assert rep.live_edges <= m_base + 2 * 256
    assert rep.n_expired_edges == rep.n_edges - (rep.live_edges - m_base)
    assert rep.fraud_recall >= 0.99
    assert rep.final_g > 0
    assert rep.n_refreshes >= 1


def test_device_service_window_capacity_is_stream_length_independent():
    """The whole point of the window: edge capacity depends on base size +
    window, not on how long the stream runs."""
    stream = make_transaction_stream(n=1000, m=5000, seed=14)
    rep = run_device_service(stream, metric="DG", batch_edges=128,
                             window_ticks=1)
    m_base = stream.base_src.shape[0]
    assert rep.live_edges <= m_base + 128
    assert rep.n_ticks == -(-stream.inc_src.shape[0] // 128)


def test_device_service_workset_matches_full_buffer():
    """Workset-engine serving (DG: unit weights, order-robust sums) must
    reproduce the full-buffer service exactly, and the bucket/fallback
    telemetry must account for every tick."""
    stream = make_transaction_stream(n=1500, m=8000, seed=15)
    kw = dict(metric="DG", batch_edges=256, max_rounds=10, window_ticks=2)
    rep_full = run_device_service(stream, **kw)
    rep_ws = run_device_service(stream, workset=True, min_bucket=64, **kw)
    assert rep_ws.final_g == rep_full.final_g
    assert rep_ws.fraud_recall == rep_full.fraud_recall
    assert rep_ws.benign_fraction == rep_full.benign_fraction
    assert rep_ws.live_edges == rep_full.live_edges
    assert rep_ws.n_workset_ticks + rep_ws.n_fallback_ticks == rep_ws.n_ticks
    assert rep_ws.max_suffix_edges > 0
    # the full-buffer service reports no workset telemetry
    assert rep_full.n_workset_ticks == rep_full.n_fallback_ticks == 0
