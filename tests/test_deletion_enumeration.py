"""Paper Appendix C: incremental edge deletion (C.1), dense-subgraph
enumeration (C.2), and time-window detection by insert+delete composition
(C.3)."""

from __future__ import annotations

import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container may lack hypothesis; property tests skip
    from _hypothesis_stub import given, settings, st

from repro.core.reference import (
    AdjGraph,
    delete_edge,
    detect,
    enumerate_communities,
    insert_edges,
    static_peel,
)


def random_graph(rng, n, m):
    g = AdjGraph(n)
    g.a[:n] = rng.integers(0, 3, n).astype(np.float64)
    edges = []
    for _ in range(m):
        u, v = rng.integers(0, n, 2)
        if u == v:
            continue
        c = float(rng.integers(1, 6))
        g.add_edge(int(u), int(v), c)
        edges.append((int(u), int(v), c))
    return g, edges


@pytest.mark.parametrize("seed", range(6))
def test_delete_matches_scratch(seed):
    rng = np.random.default_rng(seed)
    n, m = 30, 100
    g, edges = random_graph(rng, n, m)
    state = static_peel(g)
    # delete a handful of existing (combined) edges entirely
    for _ in range(10):
        u = int(rng.integers(0, n))
        if not state.graph.adj[u]:
            continue
        v = list(state.graph.adj[u].keys())[0]
        if v == u:
            continue
        delete_edge(state, u, v)
        expect = static_peel(state.graph.copy())
        np.testing.assert_array_equal(state.order(), expect.order())
        np.testing.assert_allclose(state.delta(), expect.delta())


def test_partial_weight_deletion():
    g = AdjGraph(4)
    g.add_edge(0, 1, 5.0)
    g.add_edge(1, 2, 3.0)
    g.add_edge(2, 3, 1.0)
    state = static_peel(g)
    delete_edge(state, 0, 1, c=2.0)  # partial
    assert np.isclose(state.graph.adj[0][1], 3.0)
    expect = static_peel(state.graph.copy())
    np.testing.assert_array_equal(state.order(), expect.order())


edge_strategy = st.tuples(
    st.integers(0, 9), st.integers(0, 9), st.integers(1, 5)
).filter(lambda e: e[0] != e[1])


@settings(max_examples=40, deadline=None)
@given(edges=st.lists(edge_strategy, min_size=3, max_size=30),
       which=st.integers(0, 10**6))
def test_property_delete_equals_scratch(edges, which):
    n = 10
    g = AdjGraph(n)
    for u, v, c in edges:
        g.add_edge(u, v, float(c))
    state = static_peel(g)
    u, v, _ = edges[which % len(edges)]
    if v not in state.graph.adj[u]:
        return
    delete_edge(state, u, v)
    expect = static_peel(state.graph.copy())
    np.testing.assert_array_equal(state.order(), expect.order())
    np.testing.assert_allclose(state.delta(), expect.delta())


def test_insert_then_delete_roundtrip():
    """C.3 building block: inserting then deleting an edge restores the
    exact from-scratch state of the original graph."""
    rng = np.random.default_rng(3)
    g, _ = random_graph(rng, 25, 80)
    before = static_peel(g.copy())
    state = static_peel(g.copy())
    insert_edges(state, [(3, 17, 4.0)])
    delete_edge(state, 3, 17, c=4.0)
    np.testing.assert_array_equal(state.order(), before.order())
    np.testing.assert_allclose(state.delta(), before.delta())


def test_enumerate_finds_planted_blocks():
    rng = np.random.default_rng(5)
    n = 80
    g, _ = random_graph(rng, n, 60)
    b1, b2 = np.arange(10), np.arange(40, 48)
    for blk, w in [(b1, 20.0), (b2, 12.0)]:
        for i in blk:
            for j in blk:
                if i < j:
                    g.add_edge(int(i), int(j), w)
    comms = enumerate_communities(g, max_k=3)
    assert len(comms) >= 2
    found = [set(c.tolist()) for c, _ in comms]
    assert any(set(b1.tolist()) <= f for f in found)
    assert any(set(b2.tolist()) <= f for f in found)
    # densities decreasing
    dens = [d for _, d in comms]
    assert all(dens[i] >= dens[i + 1] - 1e-9 for i in range(len(dens) - 1))
