"""Per-architecture smoke tests: every assigned (arch x shape) cell runs a
REAL step (forward/train/decode) at reduced scale on CPU through the same
code path the dry-run lowers, asserting output shapes and no NaNs."""

from __future__ import annotations

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, ARCH_FAMILY, Skip, arch_shapes
from repro.launch.cells import build_cell

CELLS = [
    (arch, shape)
    for arch in ARCHS
    for shape in arch_shapes(arch)
]


def _finite(tree) -> bool:
    ok = True
    for leaf in jax.tree.leaves(tree):
        if hasattr(leaf, "dtype") and jnp.issubdtype(leaf.dtype, jnp.floating):
            ok = ok and bool(jnp.isfinite(leaf).all())
    return ok


@pytest.mark.parametrize("arch,shape", CELLS, ids=[f"{a}-{s}" for a, s in CELLS])
def test_cell_smoke(arch, shape):
    cell = build_cell(arch, shape, concrete=True, smoke=True)
    if isinstance(cell, Skip):
        pytest.skip(cell.reason)
    out = jax.jit(cell.fn, donate_argnums=cell.donate)(*cell.args)
    if cell.step_name == "train_step":
        state, metrics = out
        assert _finite(metrics), metrics
        assert float(metrics["loss"]) > 0
        assert int(state.step) == 1
    elif cell.step_name == "prefill":
        logits, cache = out
        assert _finite(logits)
        assert logits.ndim == 2
    elif cell.step_name == "decode_step":
        logits, cache = out
        assert _finite(logits)
    elif cell.step_name in ("score_pairs", "retrieval"):
        assert _finite(out)
    elif cell.step_name == "bulk_peel":
        assert float(out.best_g) > 0
    elif cell.step_name == "insert_and_maintain":
        assert _finite(out.best_g)
    else:
        raise AssertionError(cell.step_name)
