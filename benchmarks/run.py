"""Benchmark entry point: one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--quick]

Prints ``name,us_per_call,derived`` CSV (derived: speedup/ratio per row).
The roofline/dry-run artifacts are produced separately by
``repro.launch.dryrun`` and ``benchmarks.roofline`` (multi-process, 512
host devices) and assembled by ``benchmarks.report``.
"""

from __future__ import annotations

import argparse
import os


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="smaller graphs")
    ap.add_argument("--devices", type=int, default=8,
                    help="forced XLA host devices for the sharded rows")
    ap.add_argument("--sharded-only", action="store_true",
                    help="only the dist-plane rows (BENCH_dist.json)")
    ap.add_argument("--workset-only", action="store_true",
                    help="only the workset-engine rows (BENCH_workset.json; "
                         "the CI smoke lane)")
    args = ap.parse_args()

    rows = []
    if args.workset_only:
        from benchmarks.paper_tables import bench_workset

        wskw = (dict(n=20_000, m=80_000, batch=512, window=4)
                if args.quick else {})
        rows += bench_workset(**wskw)
    elif args.sharded_only:
        # must precede jax backend init (first jax.devices() call below)
        if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
            os.environ["XLA_FLAGS"] = (
                f"--xla_force_host_platform_device_count={args.devices} "
                + os.environ.get("XLA_FLAGS", "")
            ).strip()
        from benchmarks.paper_tables import bench_sharded_peel

        skw = dict(n=20_000, m=80_000) if args.quick else {}
        rows += bench_sharded_peel(n_devices=args.devices, **skw)
    else:
        from benchmarks.paper_tables import (
            bench_device_plane,
            bench_edge_grouping,
            bench_incremental_speedup,
            bench_prevention,
            bench_window,
            bench_workset,
        )

        kw = dict(n=4000, m=20000, n_inc=600) if args.quick else {}
        rows += bench_incremental_speedup(**kw)
        rows += bench_edge_grouping(**({"n": 4000, "m": 20000, "n_inc": 600} if args.quick else {}))
        rows += bench_prevention()
        rows += bench_device_plane()
        wkw = dict(n=20_000, m=80_000, batch=512, window=4) if args.quick else {}
        rows += bench_window(**wkw)
        rows += bench_workset(**wkw)
        # sharded rows run in a subprocess: the forced multi-device
        # topology must not contaminate the legacy single-device rows
        # (this backend is already initialized single-device by now)
        import subprocess
        import sys

        cmd = [sys.executable, "-m", "benchmarks.run", "--sharded-only",
               "--devices", str(args.devices)]
        if args.quick:
            cmd.append("--quick")
        res = subprocess.run(cmd, capture_output=True, text=True)
        if res.returncode != 0:
            raise SystemExit(f"sharded benchmark subprocess failed:\n{res.stderr}")
        for line in res.stdout.strip().splitlines():
            if line.startswith("name,") or not line.strip():
                continue
            name, us, derived = line.split(",")
            rows.append((name, float(us), float(derived)))

    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.2f},{derived:.4f}")


if __name__ == "__main__":
    main()
