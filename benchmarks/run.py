"""Benchmark entry point: one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--quick]

Prints ``name,us_per_call,derived`` CSV (derived: speedup/ratio per row).
The roofline/dry-run artifacts are produced separately by
``repro.launch.dryrun`` and ``benchmarks.roofline`` (multi-process, 512
host devices) and assembled by ``benchmarks.report``.
"""

from __future__ import annotations

import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="smaller graphs")
    args = ap.parse_args()

    from benchmarks.paper_tables import (
        bench_device_plane,
        bench_edge_grouping,
        bench_incremental_speedup,
        bench_prevention,
    )

    kw = dict(n=4000, m=20000, n_inc=600) if args.quick else {}
    rows = []
    rows += bench_incremental_speedup(**kw)
    rows += bench_edge_grouping(**({"n": 4000, "m": 20000, "n_inc": 600} if args.quick else {}))
    rows += bench_prevention()
    rows += bench_device_plane()

    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.2f},{derived:.4f}")


if __name__ == "__main__":
    main()
