"""Roofline harness (§g): per (arch x shape) on the single-pod mesh, derive
the three roofline terms from compiled artifacts with *exact trip-count
accounting* and emit the table consumed by EXPERIMENTS.md.

Method (DESIGN.md §7): XLA ``cost_analysis`` counts while-loop bodies once,
so production (scan-over-layers) lowerings under-report.  The harness
therefore lowers *unrolled* analysis variants; for deep LMs it uses the
**secant-depth method** — lower unrolled depth-2 and depth-4 variants,
then

    per_layer = (cost(4) - cost(2)) / 2        (layers are identical)
    total(L)  = cost(2) + (L - 2) * per_layer

which is exact for layer-uniform programs and keeps single-core compile
times tractable.  GNN/recsys/spade cells are shallow enough to unroll
fully.

Usage:
  PYTHONPATH=src python -m benchmarks.roofline --out results/roofline \
      [--arch A --shape S] [--family lm]
"""

from __future__ import annotations

import argparse
import json
import os

from repro.launch.dryrun import run_cell  # sets XLA device-count flag on import
from repro.configs import ARCH_FAMILY, ARCHS, Skip, arch_shapes, get_config

_COST_KEYS = (
    "flops_per_chip",
    "bytes_per_chip",
    "collective_bytes_per_chip",
    "t_compute_s",
    "t_memory_s",
    "t_collective_s",
)


def _combine_secant(c2: dict, c4: dict, L: int) -> dict:
    out = dict(c4)
    for k in _COST_KEYS:
        per_layer = (c4[k] - c2[k]) / 2.0
        out[k] = c2[k] + (L - 2) * per_layer
    for c in out.get("collectives", {}):
        per_layer = (c4["collectives"][c] - c2["collectives"][c]) / 2.0
        out["collectives"][c] = c2["collectives"][c] + (L - 2) * per_layer
    out["dominant"] = max(
        [("compute", out["t_compute_s"]), ("memory", out["t_memory_s"]),
         ("collective", out["t_collective_s"])], key=lambda kv: kv[1]
    )[0]
    out["method"] = f"secant(L=2,4 -> {L})"
    return out


def roofline_cell(arch: str, shape: str, verbose: bool = True) -> dict:
    fam = ARCH_FAMILY[arch]
    spec = arch_shapes(arch)[shape]
    if isinstance(spec, Skip):
        return {"arch": arch, "shape": shape, "status": "SKIP", "reason": spec.reason}
    if fam == "lm":
        cfg = get_config(arch)
        c2 = run_cell(arch, shape, "single", verbose=False, roofline=True,
                      override_layers=2)
        c4 = run_cell(arch, shape, "single", verbose=False, roofline=True,
                      override_layers=4)
        if c2["status"] != "OK" or c4["status"] != "OK":
            return c2 if c2["status"] != "OK" else c4
        res = _combine_secant(c2, c4, cfg.n_layers)
        # model_flops from the TRUE config (the depth-override variants carry
        # a reduced-depth analytic count)
        from repro.launch.cells import build_cell

        full = build_cell(arch, shape, concrete=False)
        res["model_flops"] = full.model_flops
        res["useful_flops_ratio"] = (
            full.model_flops / (res["flops_per_chip"] * res["n_chips"])
            if res["flops_per_chip"] > 0 else 0.0
        )
    else:
        res = run_cell(arch, shape, "single", verbose=False, roofline=True)
        res["method"] = "full-unroll"
    if verbose and res.get("status") == "OK":
        print(
            f"[{arch} x {shape}] compute={res['t_compute_s']:.3e}s "
            f"memory={res['t_memory_s']:.3e}s coll={res['t_collective_s']:.3e}s "
            f"dominant={res['dominant']} useful={res['useful_flops_ratio']:.2f} "
            f"({res['method']})"
        )
    return res


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS)
    ap.add_argument("--shape")
    ap.add_argument("--family", choices=["lm", "gnn", "recsys", "spade"])
    ap.add_argument("--out", default="results/roofline")
    args = ap.parse_args()

    cells = []
    if args.arch and args.shape:
        cells = [(args.arch, args.shape)]
    else:
        for arch in ARCHS:
            if args.family and ARCH_FAMILY[arch] != args.family:
                continue
            for shape in arch_shapes(arch):
                cells.append((arch, shape))

    os.makedirs(args.out, exist_ok=True)
    fails = 0
    for arch, shape in cells:
        res = roofline_cell(arch, shape)
        if res.get("status") == "FAIL":
            fails += 1
            print(f"[{arch} x {shape}] FAIL {res.get('error')}")
        with open(os.path.join(args.out, f"{arch}__{shape}.json"), "w") as f:
            json.dump(res, f, indent=1)
    print(f"roofline done: {len(cells)} cells, {fails} failures")


if __name__ == "__main__":
    main()
