"""Assemble EXPERIMENTS.md from dry-run / roofline / bench artifacts.

    PYTHONPATH=src python -m benchmarks.report \
        --dryrun results/dryrun --roofline results/roofline \
        --bench bench_output.txt --perf EXPERIMENTS_PERF.md \
        --out EXPERIMENTS.md
"""

from __future__ import annotations

import argparse
import glob
import json
import os

ARCH_ORDER = [
    "mixtral-8x7b", "olmoe-1b-7b", "internlm2-20b", "deepseek-coder-33b",
    "qwen3-14b", "meshgraphnet", "gat-cora", "dimenet", "gcn-cora",
    "two-tower-retrieval", "spade-grab",
]


def _gb(x):
    return f"{x / 1e9:.2f}" if x is not None else "-"


def _load(directory):
    out = {}
    for fn in glob.glob(os.path.join(directory, "*.json")):
        with open(fn) as f:
            r = json.load(f)
        out[(r.get("arch"), r.get("shape"), r.get("mesh", "single"))] = r
    return out


def _advice(r) -> str:
    dom = r.get("dominant")
    fam = r.get("arch", "")
    if dom == "collective":
        if "two-tower" in fam:
            return ("shard lookups hierarchically (local-hot rows replicated) to cut "
                    "cross-chip gather traffic")
        return ("reduce per-layer param all-gathers: larger microbatches amortize "
                "FSDP gathers, or switch the axis to pure-DP + sharded optimizer")
    if dom == "memory":
        if "decode" in r.get("shape", "") or "500k" in r.get("shape", ""):
            return "KV-cache reads dominate: quantize KV to int8 or widen batch per chip"
        return "fuse elementwise chains / remat less; raise arithmetic intensity per HBM byte"
    return "compute-bound: raise MXU utilization (larger tiles, bf16 accumulation)"


def dryrun_section(dr: dict) -> list[str]:
    lines = [
        "## §Dry-run (deliverable e) — lower+compile on the production meshes",
        "",
        "512 host devices stand in for 2x16x16 TPU v5e chips; every cell is",
        "`jit(step).lower(ShapeDtypeStructs).compile()` — zero allocation.",
        "`args` = per-device input bytes (sharded params/state/cache);",
        "`temp` = XLA per-device temp allocation (CPU backend: scan bodies are",
        "counted without TPU-grade buffer reuse/aliasing, so treat as upper bound).",
        "",
        "| arch | shape | mesh | status | args GB/dev | temp GB/dev | compile s | collectives (per-chip bytes by type) |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for arch in ARCH_ORDER:
        for (a, s, m), r in sorted(dr.items(), key=lambda kv: (kv[0][1] or "", kv[0][2] or "")):
            if a != arch:
                continue
            if r["status"] == "SKIP":
                lines.append(f"| {a} | {s} | {m} | SKIP({r['reason'][:40]}...) | - | - | - | - |")
                continue
            if r["status"] == "FAIL":
                lines.append(f"| {a} | {s} | {m} | **FAIL** {r['error'][:60]} | - | - | - | - |")
                continue
            coll = ", ".join(
                f"{k.split('-')[-1][:7]}:{_gb(v)}G" for k, v in r["collectives"].items() if v
            ) or "none"
            lines.append(
                f"| {a} | {s} | {m} | OK | {_gb(r['argument_bytes'])} | "
                f"{_gb(r['bytes_per_device'])} | {r['compile_s']} | {coll} |"
            )
    return lines


def roofline_section(rf: dict) -> list[str]:
    lines = [
        "## §Roofline (deliverable g) — single-pod (256 x v5e: 197 TF/s bf16, 819 GB/s HBM, 50 GB/s ICI)",
        "",
        "Terms from trip-count-exact lowerings (unrolled / secant-depth; DESIGN.md §7).",
        "`useful` = MODEL_FLOPS / (HLO FLOPs x chips); < 1 exposes remat/dispatch",
        "overhead, > would flag undercounting. Memory bytes come from XLA's",
        "`bytes accessed` on the CPU-compiled module, which counts unfused",
        "intermediates — a pessimistic (upper-bound) HBM proxy; the *relative*",
        "movement of this term under optimization is what §Perf tracks.",
        "",
        "| arch | shape | compute s | memory s | collective s | dominant | MODEL_FLOPS | useful | next move |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for arch in ARCH_ORDER:
        for (a, s, m), r in sorted(rf.items(), key=lambda kv: kv[0][1] or ""):
            if a != arch:
                continue
            if r.get("status") == "SKIP":
                lines.append(f"| {a} | {s} | - | - | - | SKIP | - | - | {r['reason'][:50]} |")
                continue
            if r.get("status") != "OK":
                lines.append(f"| {a} | {s} | - | - | - | FAIL | - | - | {r.get('error','')[:50]} |")
                continue
            lines.append(
                f"| {a} | {s} | {r['t_compute_s']:.2e} | {r['t_memory_s']:.2e} | "
                f"{r['t_collective_s']:.2e} | {r['dominant']} | {r['model_flops']:.2e} | "
                f"{r['useful_flops_ratio']:.2f} | {_advice(r)} |"
            )
    return lines


def bench_section(path: str | None) -> list[str]:
    lines = [
        "## §Paper-validation — Spade's own claims (host oracle, scaled datasets)",
        "",
        "Synthetic power-law streams matched to Table 3 statistics (no network",
        "access; ratios are the claims). `derived` = speedup vs static / ratio.",
        "",
        "Reading guide: `table4_*` reproduces the incremental-vs-static speedup",
        "and its batch-size scaling (up to ~1.8e3x at 1e5 edges; the paper's 1e6x",
        "is the same scale-invariant incremental cost against a 25M-edge static",
        "run). `fig9a_*`/`fig11_*` reproduce the collusion case study: prevention",
        "~0.90 (paper: 0.86-0.92), recall 1.0, and edge grouping 4.0x faster per",
        "edge than per-edge reordering. `table5_*` shows grouping SLOWER than",
        "blind 1K batching on hub-heavy background streams — many hub-incident",
        "edges are urgent under Def 4.1, so grouping pays extra reorders; in the",
        "paper's Grab data the benign majority dominates (their Fig 9b regime),",
        "which our fig9a collusion stream reproduces. Both behaviours are the",
        "same engine; the split is a property of the stream, reported honestly.",
        "",
        "```",
    ]
    if path and os.path.exists(path):
        with open(path) as f:
            lines += [ln.rstrip() for ln in f if "," in ln]
    else:
        lines.append("(run `PYTHONPATH=src python -m benchmarks.run | tee bench_output.txt` first)")
    lines.append("```")
    return lines


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dryrun", default="results/dryrun")
    ap.add_argument("--roofline", default="results/roofline")
    ap.add_argument("--bench", default="bench_output.txt")
    ap.add_argument("--perf", default="EXPERIMENTS_PERF.md")
    ap.add_argument("--out", default="EXPERIMENTS.md")
    args = ap.parse_args()

    dr = _load(args.dryrun)
    dr = {k: v for k, v in dr.items() if v.get("variant") != "roofline"}
    rf = _load(args.roofline)

    lines = [
        "# EXPERIMENTS — Spade on JAX/TPU",
        "",
        "Produced by `repro.launch.dryrun` (production lowerings, both meshes),",
        "`benchmarks.roofline` (trip-count-exact analysis lowerings), and",
        "`benchmarks.run` (paper-table reproduction). Regenerate with",
        "`python -m benchmarks.report`.",
        "",
    ]
    lines += bench_section(args.bench) + [""]
    lines += dryrun_section(dr) + [""]
    lines += roofline_section(rf) + [""]
    if os.path.exists(args.perf):
        with open(args.perf) as f:
            lines += [f.read()]
    with open(args.out, "w") as f:
        f.write("\n".join(lines) + "\n")
    n_ok = sum(1 for r in dr.values() if r["status"] == "OK")
    n_fail = sum(1 for r in dr.values() if r["status"] == "FAIL")
    print(f"wrote {args.out}: dryrun {n_ok} OK / {n_fail} FAIL / "
          f"{sum(1 for r in dr.values() if r['status'] == 'SKIP')} SKIP; "
          f"roofline {len(rf)} cells")


if __name__ == "__main__":
    main()
