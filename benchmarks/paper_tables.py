"""Benchmarks reproducing the paper's tables/figures (scaled to this
container; the paper's claims are *ratios*, which transfer):

* ``bench_incremental_speedup`` — Fig 10 + Table 4: static peel vs
  incremental reorder per edge, batch sizes |ΔE| ∈ {1, 10, 100, 1K}.
* ``bench_edge_grouping``       — Table 5: IncXG vs IncX-1K elapsed/edge.
* ``bench_prevention``          — Fig 9a / §5.2: prevention ratio & latency.
* ``bench_device_plane``        — TPU-native plane: bulk peel + incremental
  maintenance wall-times (CPU backend; ratios again).
* ``bench_window``              — Appendix C.3 sliding-window serving:
  steady-state warm tick (expire + insert suffix re-peels) vs a full
  from-scratch bulk re-peel per tick; emits ``BENCH_window.json``.
* ``bench_workset``             — affected-area workset engine (DESIGN.md
  §8): bucketed workset tick vs full-buffer warm tick, hot/cold, plus
  per-bucket warm re-peel rows; emits ``BENCH_workset.json``.

Every row prints ``name,us_per_call,derived`` CSV (derived = speedup /
ratio / aux metric for that row).
"""

from __future__ import annotations

import time

import numpy as np

from repro.core.metrics import make_metric
from repro.core.reference import AdjGraph, detect, insert_edges, static_peel
from repro.core.spade import Spade
from repro.graphstore.generators import make_transaction_stream
from repro.serve import EngineSpec, SpadeService

Row = tuple[str, float, float]


def _build_graph(metric, stream, frac=1.0):
    m = int(stream.base_src.shape[0] * frac)
    sp = Spade(metric=metric)
    sp.LoadGraph(stream.base_src[:m], stream.base_dst[:m], stream.base_amt[:m],
                 n_vertices=stream.n_vertices)
    return sp


def bench_incremental_speedup(
    n=16000, m=100000, n_inc=2000, batches=(1, 10, 100, 1000), seed=0
) -> list[Row]:
    """Fig 10 / Table 4 (wiki-vote-scale replica)."""
    rows: list[Row] = []
    stream = make_transaction_stream(n=n, m=m, inc_fraction=0.05, seed=seed)
    for name in ("DG", "DW", "FD"):
        sp = _build_graph(name, stream)
        # static from-scratch run (the per-insertion cost of the baseline)
        t0 = time.perf_counter()
        static_peel(sp.graph.copy())
        t_static = time.perf_counter() - t0
        rows.append((f"fig10_static_{name}", t_static * 1e6, 1.0))

        inc = list(zip(stream.inc_src.tolist(), stream.inc_dst.tolist(),
                       stream.inc_amt.tolist()))[:n_inc]
        for b in batches:
            spb = _build_graph(name, stream)
            t0 = time.perf_counter()
            i = 0
            while i < len(inc):
                spb.InsertBatchEdges(inc[i : i + b])
                i += b
            dt = time.perf_counter() - t0
            us_per_edge = dt / len(inc) * 1e6
            speedup = (t_static * 1e6) / max(us_per_edge, 1e-9)
            rows.append((f"table4_Inc{name}_batch{b}", us_per_edge, speedup))
    return rows


def bench_edge_grouping(n=16000, m=100000, n_inc=2000, seed=1) -> list[Row]:
    """Table 5: edge grouping (IncXG) vs fixed 1K batches (IncX-1K)."""
    rows: list[Row] = []
    stream = make_transaction_stream(n=n, m=m, inc_fraction=0.05, seed=seed)
    inc = list(zip(stream.inc_src.tolist(), stream.inc_dst.tolist(),
                   stream.inc_amt.tolist()))[:n_inc]
    for name in ("DG", "DW", "FD"):
        # fixed 1K batches
        sp = _build_graph(name, stream)
        t0 = time.perf_counter()
        for i in range(0, len(inc), 1000):
            sp.InsertBatchEdges(inc[i : i + 1000])
        t_batch = (time.perf_counter() - t0) / len(inc) * 1e6
        # grouping: benign edges buffer, urgent flush immediately
        spg = Spade(metric=name, edge_grouping=True)
        spg.LoadGraph(stream.base_src, stream.base_dst, stream.base_amt,
                      n_vertices=stream.n_vertices)
        t0 = time.perf_counter()
        for e in inc:
            spg.InsertEdge(*e)
        spg.FlushBuffer()
        t_group = (time.perf_counter() - t0) / len(inc) * 1e6
        rows.append((f"table5_Inc{name}-1K", t_batch, 1.0))
        rows.append((f"table5_Inc{name}G", t_group, t_batch / max(t_group, 1e-9)))
    return rows


def bench_prevention(seed=2) -> list[Row]:
    """Fig 9a / §5.2: prevention ratio + detection latency, grouping on/off."""
    rows: list[Row] = []
    for grouping in (False, True):
        stream = make_transaction_stream(n=8000, m=40000, seed=seed)
        rep = SpadeService("DW", EngineSpec(
            plane="host", grouping=grouping, batch_edges=1, flush_every=0.5,
        )).run(stream)
        tag = "grouping" if grouping else "batch1"
        rows.append((f"fig9a_prevention_{tag}", rep.mean_us_per_edge,
                     rep.prevention_ratio if rep.prevention_ratio is not None else -1.0))
        rows.append((f"fig9a_recall_{tag}", rep.mean_us_per_edge, rep.fraud_recall))
        rows.append((f"fig11_latency_{tag}", rep.mean_us_per_edge,
                     rep.detection_latency_s if rep.detection_latency_s is not None else -1.0))
    return rows


def bench_device_plane(seed=3) -> list[Row]:
    """TPU-native plane on the CPU backend: bulk peel + incremental tick."""
    import jax
    import jax.numpy as jnp

    from repro.core.incremental import init_state, insert_and_maintain
    from repro.core.peel import bulk_peel
    from repro.graphstore.structs import device_graph_from_coo

    rows: list[Row] = []
    rng = np.random.default_rng(seed)
    n, m = 100_000, 400_000
    src = rng.integers(0, n, m)
    dst = rng.integers(0, n, m)
    keep = src != dst
    g = device_graph_from_coo(n, src[keep], dst[keep],
                              np.ones(keep.sum(), np.float32),
                              e_capacity=keep.sum() + 65536)
    t0 = time.perf_counter()
    res = jax.block_until_ready(bulk_peel(g, eps=0.1))
    t_first = time.perf_counter() - t0
    t0 = time.perf_counter()
    res = jax.block_until_ready(bulk_peel(g, eps=0.1))
    t_bulk = time.perf_counter() - t0
    rows.append(("device_bulk_peel_100k", t_bulk * 1e6, float(res.n_rounds)))
    rows.append(("device_bulk_peel_compile", t_first * 1e6, t_first / max(t_bulk, 1e-9)))

    state = init_state(g, eps=0.1)
    B = 1024
    bs = jnp.asarray(rng.integers(0, n, B), jnp.int32)
    bd = jnp.asarray(rng.integers(0, n, B), jnp.int32)
    bc = jnp.ones(B, jnp.float32)
    valid = bs != bd
    state = jax.block_until_ready(
        insert_and_maintain(state, bs, bd, bc, valid, eps=0.1)
    )  # compile
    t0 = time.perf_counter()
    reps = 5
    for _ in range(reps):
        state = insert_and_maintain(state, bs, bd, bc, valid, eps=0.1)
    jax.block_until_ready(state.best_g)
    t_inc = (time.perf_counter() - t0) / reps
    rows.append(("device_incremental_1024", t_inc * 1e6, t_inc / B * 1e6))
    return rows


class _WindowBenchEnv:
    """Shared harness for the sliding-window benches (``bench_window`` /
    ``bench_workset``): base graph factory, hot-pool probe, and a regime
    runner.  Every regime re-seeds its own batch stream, so any two
    regimes (and both engines) replay IDENTICAL transaction sequences —
    suffix sizes drive the tick cost, so comparing different streams
    would compare unlike workloads."""

    def __init__(self, n, m, batch, window, seed):
        self.n, self.batch, self.window, self.seed = n, batch, window, seed
        rng = np.random.default_rng(seed)
        src = rng.integers(0, n, m)
        dst = rng.integers(0, n, m)
        keep = src != dst
        self.m_base = int(keep.sum())
        self._coo = (src[keep], dst[keep])
        # hot pool: the vertices the last peel removed in the final rounds
        probe = self.fresh_state()
        lv = np.asarray(probe.level)
        lv = np.where(np.asarray(probe.graph.vertex_mask), lv, -1)
        self.hot_pool = np.argsort(lv)[-max(batch // 2, 64):]

    def fresh_state(self):
        from repro.core.incremental import init_state
        from repro.graphstore.structs import device_graph_from_coo

        g = device_graph_from_coo(
            self.n, *self._coo, np.ones(self.m_base, np.float32),
            e_capacity=self.m_base + (self.window + 1) * self.batch,
        )
        return init_state(g, eps=0.1)

    def run_regime(self, hot_pool, workset=False, reps=5):
        """Steady-state mean tick seconds for one traffic regime.

        Returns ``(tick_seconds, final_state, telemetry)``; telemetry is
        all zeros for the fused full-buffer engine."""
        import jax
        import jax.numpy as jnp

        from repro.core.incremental import (
            slide_and_maintain,
            slide_and_maintain_auto,
        )

        state = self.fresh_state()
        n, batch, window, m_base = self.n, self.batch, self.window, self.m_base
        slot_ids = jnp.arange(state.graph.e_capacity, dtype=jnp.int32)
        ring: list[int] = []
        telemetry = {"workset": 0, "fallback": 0, "max_e_bucket": 0}
        rng = np.random.default_rng(self.seed + 100)  # per-regime stream

        def make_batch():
            if hot_pool is None:
                bs, bd = rng.integers(0, n, batch), rng.integers(0, n, batch)
            else:
                bs, bd = rng.choice(hot_pool, batch), rng.choice(hot_pool, batch)
            bs = jnp.asarray(bs, jnp.int32)
            bd = jnp.asarray(bd, jnp.int32)
            return bs, bd, jnp.ones(batch, jnp.float32), bs != bd

        def tick(state):
            cnt0 = ring.pop(0) if len(ring) >= window else 0
            drop = (slot_ids >= m_base) & (slot_ids < m_base + cnt0)
            bs, bd, bc, valid = make_batch()
            if workset:
                state, info = slide_and_maintain_auto(
                    state, drop, bs, bd, bc, valid, eps=0.1
                )
                telemetry["fallback" if info.fallback else "workset"] += 1
                telemetry["max_e_bucket"] = max(
                    telemetry["max_e_bucket"], info.e_bucket
                )
            else:
                state = slide_and_maintain(state, drop, bs, bd, bc, valid,
                                           eps=0.1)
            ring.append(int(jnp.sum(valid)))
            return state

        for _ in range(window + 1):  # fill the window + warm compile caches
            state = tick(state)
        jax.block_until_ready(state.best_g)
        t0 = time.perf_counter()
        for _ in range(reps):
            state = tick(state)
        jax.block_until_ready(state.best_g)
        return (time.perf_counter() - t0) / reps, state, telemetry


def bench_window(
    n=100_000,
    m=400_000,
    batch=1024,
    window=8,
    seed=4,
    out_json="BENCH_window.json",
) -> list[Row]:
    """Sliding-window serving (paper Appendix C.3, device plane): the fused
    warm tick (``slide_and_maintain``: expire + insert + one suffix
    re-peel) vs the naive alternative of a full from-scratch bulk re-peel
    per tick, in two traffic regimes:

    * **cold** — uniform random endpoints: some endpoint almost surely
      peeled in round 0, so ``r0 = 0`` and the warm tick degenerates to a
      full re-peel plus compaction overhead (the honest worst case).
    * **hot**  — the paper's fraud-burst case study: traffic concentrated
      on the currently-densest vertices (high peel level), so the
      re-peeled suffix is small and the warm tick wins on round count.

    Writes ``out_json`` so the perf trajectory is recorded per commit."""
    import json

    import jax

    from repro.core.peel import bulk_peel

    env = _WindowBenchEnv(n, m, batch, window, seed)
    t_cold, state, _ = env.run_regime(None)
    t_hot, _, _ = env.run_regime(env.hot_pool)

    # naive alternative: full bulk re-peel of the resident graph per tick
    res = jax.block_until_ready(bulk_peel(state.graph, eps=0.1))  # compile
    reps = 5
    t0 = time.perf_counter()
    for _ in range(reps):
        res = bulk_peel(state.graph, eps=0.1)
    jax.block_until_ready(res.best_g)
    t_scratch = (time.perf_counter() - t0) / reps

    rows: list[Row] = [
        ("window_slide_tick_cold", t_cold * 1e6, t_scratch / max(t_cold, 1e-9)),
        ("window_slide_tick_hot", t_hot * 1e6, t_scratch / max(t_hot, 1e-9)),
        ("window_full_repeel", t_scratch * 1e6, float(res.n_rounds)),
    ]
    if out_json:
        with open(out_json, "w") as f:
            json.dump(
                {
                    "n": int(n), "m": int(m), "batch": int(batch),
                    "window": int(window),
                    "rows": {r[0]: {"us": r[1], "derived": r[2]} for r in rows},
                },
                f, indent=1,
            )
    return rows


def bench_workset(
    n=100_000,
    m=400_000,
    batch=1024,
    window=8,
    seed=4,
    out_json="BENCH_workset.json",
) -> list[Row]:
    """Affected-area workset engine (DESIGN.md §8) vs the full-buffer warm
    tick, same setup as :func:`bench_window`:

    * **hot ticks** — fraud-burst traffic on the densest vertices: the
      affected suffix is small, the workset engine gathers it into
      bucket-sized buffers and every re-peel round touches O(|suffix|)
      instead of O(E_capacity).
    * **cold ticks** — uniform traffic: the suffix swallows the graph and
      the engine falls back to the full-buffer path (tick ≈ full tick +
      the one-transfer count sync).
    * **per-bucket rows** — the warm suffix re-peel alone (no structural
      update), workset vs full-buffer, across suffix sizes landing in
      successive power-of-two buckets.

    Writes ``out_json`` so the perf trajectory is recorded per commit."""
    import json

    import jax
    import jax.numpy as jnp

    from repro.core.peel import (
        bulk_peel_warm,
        bulk_peel_warm_workset,
        select_bucket,
        workset_sizes,
    )

    env = _WindowBenchEnv(n, m, batch, window, seed)
    t_full_hot, state_hot, _ = env.run_regime(env.hot_pool, workset=False)
    t_ws_hot, _, tel_hot = env.run_regime(env.hot_pool, workset=True)
    t_full_cold, _, _ = env.run_regime(None, workset=False)
    t_ws_cold, _, tel_cold = env.run_regime(None, workset=True)

    rows: list[Row] = [
        ("workset_tick_hot", t_ws_hot * 1e6, t_full_hot / max(t_ws_hot, 1e-9)),
        ("workset_tick_cold", t_ws_cold * 1e6,
         t_full_cold / max(t_ws_cold, 1e-9)),
        ("workset_full_tick_hot", t_full_hot * 1e6, 1.0),
        ("workset_full_tick_cold", t_full_cold * 1e6, 1.0),
    ]

    # per-bucket rows: the warm re-peel alone over suffixes of growing size
    g = state_hot.graph
    lv = np.where(np.asarray(g.vertex_mask), np.asarray(state_hot.level), -1)
    order = np.argsort(lv)
    seen: set[int] = set()
    for k in (max(batch // 4, 64), batch, 4 * batch, 16 * batch):
        if k > n:
            continue
        kmask = jnp.zeros(g.n_capacity, bool).at[
            jnp.asarray(order[-k:], jnp.int32)
        ].set(True)
        nv, ne = workset_sizes(g, kmask)
        bv = select_bucket(int(nv), g.n_capacity)
        be = select_bucket(int(ne), g.e_capacity)
        if bv is None or be is None or be in seen:
            continue
        seen.add(be)
        reps = 3

        def timed(f):
            out = jax.block_until_ready(f())  # compile
            t0 = time.perf_counter()
            for _ in range(reps):
                out = f()
            jax.block_until_ready(out)
            return (time.perf_counter() - t0) / reps

        t_ws = timed(lambda: bulk_peel_warm_workset(
            g, kmask, prior_best_g=state_hot.best_g, eps=0.1, max_rounds=20,
            v_bucket=bv, e_bucket=be,
        ))
        t_fb = timed(lambda: bulk_peel_warm(
            g, kmask, prior_best_g=state_hot.best_g, eps=0.1, max_rounds=20,
        ))
        rows.append((f"workset_peel_b{be}", t_ws * 1e6,
                     t_fb / max(t_ws, 1e-9)))

    if out_json:
        with open(out_json, "w") as f:
            json.dump(
                {
                    "n": int(n), "m": int(m), "batch": int(batch),
                    "window": int(window),
                    "hot_ticks": tel_hot, "cold_ticks": tel_cold,
                    "rows": {r[0]: {"us": r[1], "derived": r[2]} for r in rows},
                },
                f, indent=1,
            )
    return rows


def bench_sharded_peel(
    n=100_000,
    m=400_000,
    n_devices=8,
    seed=3,
    batch=1024,
    out_json="BENCH_dist.json",
) -> list[Row]:
    """Dist plane: bulk peel + incremental tick, single device vs an
    n-device edge-sharded mesh (forced CPU host devices; ratios transfer).
    Writes ``out_json`` so the perf trajectory is recorded per commit."""
    import json

    import jax
    import jax.numpy as jnp

    from repro.core.incremental import init_state, insert_and_maintain
    from repro.core.peel import bulk_peel
    from repro.dist.graph import (
        init_sharded_state,
        shard_graph,
        sharded_bulk_peel,
        sharded_insert_and_maintain,
    )
    from repro.graphstore.structs import device_graph_from_coo

    nd = min(n_devices, len(jax.devices()))
    mesh = jax.make_mesh((nd,), ("data",))
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n, m)
    dst = rng.integers(0, n, m)
    keep = src != dst
    g = device_graph_from_coo(
        n, src[keep], dst[keep], np.ones(keep.sum(), np.float32),
        e_capacity=keep.sum() + 65536,
    )
    gs = shard_graph(g, mesh)

    def timed(f, reps=3):
        out = jax.block_until_ready(f())  # compile
        t0 = time.perf_counter()
        for _ in range(reps):
            out = f()
        jax.block_until_ready(out)
        return (time.perf_counter() - t0) / reps, out

    t1, res1 = timed(lambda: bulk_peel(g, eps=0.1))
    tn, resn = timed(lambda: sharded_bulk_peel(gs, mesh, eps=0.1))
    assert float(resn.best_g) == float(res1.best_g)  # unit weights: exact
    rows: list[Row] = [
        ("sharded_bulk_peel_1dev", t1 * 1e6, float(res1.n_rounds)),
        (f"sharded_bulk_peel_{nd}dev", tn * 1e6, t1 / max(tn, 1e-9)),
    ]

    bs = jnp.asarray(rng.integers(0, n, batch), jnp.int32)
    bd = jnp.asarray(rng.integers(0, n, batch), jnp.int32)
    bc = jnp.ones(batch, jnp.float32)
    valid = bs != bd
    reps = 5

    state = init_state(g, eps=0.1)
    state = insert_and_maintain(state, bs, bd, bc, valid, eps=0.1, max_rounds=20)
    t0 = time.perf_counter()
    for _ in range(reps):
        state = insert_and_maintain(state, bs, bd, bc, valid, eps=0.1, max_rounds=20)
    jax.block_until_ready(state.best_g)
    t_i1 = (time.perf_counter() - t0) / reps

    state = init_sharded_state(gs, mesh, eps=0.1)
    state = sharded_insert_and_maintain(
        state, bs, bd, bc, valid, mesh=mesh, eps=0.1, max_rounds=20
    )
    t0 = time.perf_counter()
    for _ in range(reps):
        state = sharded_insert_and_maintain(
            state, bs, bd, bc, valid, mesh=mesh, eps=0.1, max_rounds=20
        )
    jax.block_until_ready(state.best_g)
    t_in = (time.perf_counter() - t0) / reps
    rows.append(("sharded_tick_1dev", t_i1 * 1e6, t_i1 / batch * 1e6))
    rows.append((f"sharded_tick_{nd}dev", t_in * 1e6, t_i1 / max(t_in, 1e-9)))

    if out_json:
        with open(out_json, "w") as f:
            json.dump(
                {
                    "n": int(n), "m": int(m), "devices": int(nd),
                    "batch": int(batch),
                    "rows": {r[0]: {"us": r[1], "derived": r[2]} for r in rows},
                },
                f, indent=1,
            )
    return rows
